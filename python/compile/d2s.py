"""Dense-to-sparse (D2S) transformation — Monarch projection (paper §III-A).

Analytical projection of a dense ``n x n`` matrix ``W`` onto the Monarch
class ``M = P L P R P`` minimizing ``||W - M||_F`` (Dao et al. 2022):
by the slice identity (see ``kernels/ref.py``)

    M[(d, a), (c, k)] = L[a][d, k] * R[k][a, c]

each ``b x b`` slice ``A^(a,k)[d, c] = W[(d, a), (c, k)]`` of a Monarch
matrix is rank-1, so the Frobenius-optimal projection is the best rank-1
approximation of every slice independently (SVD truncation):

    A^(a,k) ~= sigma * u v^T,   L[a][:, k] = sqrt(sigma) u,
                                R[k][a, :] = sqrt(sigma) v^T.

This Python implementation is the build-time twin of
``rust/src/monarch/project.rs``; both are tested for parity against
``ref.monarch_dense``.
"""

from __future__ import annotations

import numpy as np


def monarch_project(W: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Project dense ``W`` (n x n, n = b^2) onto the Monarch class.

    Returns ``(L, R)`` each of shape ``(b, b, b)``.
    """
    n, n2 = W.shape
    assert n == n2, "W must be square"
    b = int(round(np.sqrt(n)))
    assert b * b == n, f"n ({n}) must be a perfect square"

    # W[(d, a), (c, k)] -> slices[a, k, d, c]
    w4 = W.reshape(b, b, b, b)  # [d, a, c, k]
    slices = w4.transpose(1, 3, 0, 2)  # [a, k, d, c]

    # Batched rank-1 SVD over all b^2 slices at once.
    u, s, vt = np.linalg.svd(slices.reshape(b * b, b, b), full_matrices=False)
    u1 = u[:, :, 0].reshape(b, b, b)  # [a, k, d]
    v1 = vt[:, 0, :].reshape(b, b, b)  # [a, k, c]
    s1 = np.sqrt(s[:, 0]).reshape(b, b)  # [a, k]

    L = np.zeros((b, b, b), W.dtype)  # L[a][d, k]
    R = np.zeros((b, b, b), W.dtype)  # R[k][a, c]
    L[:] = (u1 * s1[:, :, None]).transpose(0, 2, 1)  # [a, d, k]
    R[:] = (v1 * s1[:, :, None]).transpose(1, 0, 2)  # [k, a, c]
    return L, R


def monarch_dense_np(L: np.ndarray, R: np.ndarray) -> np.ndarray:
    """Dense materialization of ``M = P L P R P`` (numpy twin of
    ``ref.monarch_dense``)."""
    b = L.shape[0]
    m4 = np.einsum("adk,kac->dack", L, R)
    return m4.reshape(b * b, b * b)


def projection_error(W: np.ndarray) -> float:
    """Relative Frobenius error of the Monarch projection of ``W``."""
    L, R = monarch_project(W)
    M = monarch_dense_np(L, R)
    return float(np.linalg.norm(W - M) / max(np.linalg.norm(W), 1e-30))


def random_monarch(b: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Random Monarch factors (for exact-recovery tests)."""
    rng = np.random.default_rng(seed)
    L = rng.standard_normal((b, b, b)).astype(np.float32)
    R = rng.standard_normal((b, b, b)).astype(np.float32)
    return L, R
