"""Layer-1 Pallas kernels: block-diagonal and Monarch matrix multiply.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
analog-CIM crossbar holds one ``b x b`` weight block stationary while the
input segment streams through. On a TPU-shaped machine the analogue is a
VMEM-resident weight tile driven by a Pallas grid over the block index;
the HBM->VMEM ``BlockSpec`` schedule plays the role of the array-write
schedule, and the ``b x b`` contraction targets the MXU.

All kernels here are lowered with ``interpret=True`` so the surrounding
JAX program compiles to plain HLO and runs on any PJRT backend (the Rust
coordinator uses the CPU client). Real-TPU lowering would emit Mosaic
custom-calls that CPU PJRT cannot execute.

Correctness oracle: ``ref.py`` (pytest + hypothesis sweep shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Pallas kernels in this repo always run in interpret mode (CPU PJRT).
INTERPRET = True


def _block_diag_kernel(x_ref, w_ref, o_ref):
    """One grid step: multiply input segment ``k`` by stationary block ``k``.

    ``x_ref``: (batch, b) VMEM tile — segment ``k`` of the input rows.
    ``w_ref``: (1, b, b) VMEM tile — block ``k`` (weight-stationary).
    ``o_ref``: (batch, b) VMEM tile — segment ``k`` of the output rows.

    The contraction is written as a plain matmul so it maps onto the MXU
    when compiled for a real TPU: (batch, b) @ (b, b)^T.
    """
    w = w_ref[0]  # (b, b): o[d] = sum_c w[d, c] x[c]
    o_ref[...] = jnp.dot(
        x_ref[...], w.T, preferred_element_type=o_ref.dtype
    )


def block_diag_mm(blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Pallas block-diagonal multiply.

    ``blocks``: (nb, b, b); ``x``: (batch, nb*b). Returns (batch, nb*b)
    with segment ``k`` of every row multiplied by ``blocks[k]``
    (``y = x_seg @ blocks[k].T``, matching ``ref.block_diag_mm``).
    """
    nb, b, b2 = blocks.shape
    assert b == b2, "blocks must be square"
    batch, n = x.shape
    assert n == nb * b, f"input dim {n} != nb*b ({nb}*{b})"

    grid = (nb,)
    return pl.pallas_call(
        _block_diag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, b), lambda k: (0, k)),
            pl.BlockSpec((1, b, b), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, b), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=INTERPRET,
    )(x, blocks)


def _block_diag_lanes_kernel(x_ref, w_ref, o_ref, *, lanes: int):
    """DenseMap-style lane-sequential variant of the block-diagonal kernel.

    Models the capacity-optimized CIM mapping where one physical array
    stores ``lanes`` diagonals and processes them *temporally*: the grid
    walks (array, lane) with the lane axis minor, accumulating into the
    same VMEM output tile — mirroring the scheduler's per-lane row
    activation with shift-and-add accumulation.

    ``x_ref``: (batch, b) — input segment for (array, lane).
    ``w_ref``: (1, b, b) — the block held by this lane of this array.
    ``o_ref``: (batch, lanes*b) — output tile of the whole array.
    """
    lane = pl.program_id(1)
    w = w_ref[0]
    seg = jnp.dot(x_ref[...], w.T, preferred_element_type=o_ref.dtype)
    b = seg.shape[-1]
    o_ref[:, pl.dslice(lane * b, b)] = seg


def block_diag_mm_lanes(
    blocks: jnp.ndarray, x: jnp.ndarray, lanes: int
) -> jnp.ndarray:
    """Lane-sequential block-diagonal multiply (DenseMap emulation).

    Identical numerics to :func:`block_diag_mm`; the grid is reshaped to
    (arrays, lanes) so blocks belonging to the same physical array are
    visited sequentially, which is the iteration order the DenseMap
    scheduler imposes on real CIM hardware.
    """
    nb, b, _ = blocks.shape
    batch, n = x.shape
    assert nb % lanes == 0, f"nb ({nb}) must be divisible by lanes ({lanes})"
    arrays = nb // lanes

    return pl.pallas_call(
        functools.partial(_block_diag_lanes_kernel, lanes=lanes),
        grid=(arrays, lanes),
        in_specs=[
            pl.BlockSpec((batch, b), lambda a, l: (0, a * lanes + l)),
            pl.BlockSpec((1, b, b), lambda a, l: (a * lanes + l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, lanes * b), lambda a, l: (0, a)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=INTERPRET,
    )(x, blocks)


def monarch_mm(L: jnp.ndarray, R: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Monarch multiply ``y = (P L P R P) x`` for batched rows ``x``.

    The two block-diagonal stages run as Pallas kernels; the fixed stride
    permutations are pure data movement (reshape/transpose) and lower to
    HLO transposes that XLA fuses with neighbouring ops — exactly like the
    paper's folded-permutation execution, where P never costs a FLOP.
    """
    b = L.shape[0]
    u = ref.perm(x, b)
    v = block_diag_mm(R, u)
    w = ref.perm(v, b)
    z = block_diag_mm(L, w)
    return ref.perm(z, b)


def monarch_mm_lanes(
    L: jnp.ndarray, R: jnp.ndarray, x: jnp.ndarray, lanes: int
) -> jnp.ndarray:
    """Monarch multiply using the lane-sequential (DenseMap) stages."""
    b = L.shape[0]
    u = ref.perm(x, b)
    v = block_diag_mm_lanes(R, u, lanes)
    w = ref.perm(v, b)
    z = block_diag_mm_lanes(L, w, lanes)
    return ref.perm(z, b)


def _block_diag_adc_kernel(x_ref, w_ref, o_ref, *, bits: int, full_scale: float):
    """Block-diagonal multiply with SAR-ADC readout quantization.

    Each column current is digitized by a ``bits``-bit ADC over
    ``[-full_scale, full_scale]`` — the analog-CIM readout model used to
    study DenseMap's reduced-precision operating point.
    """
    w = w_ref[0]
    acc = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)
    levels = (1 << bits) - 1
    step = 2.0 * full_scale / levels
    half = levels // 2
    q = jnp.clip(jnp.round(acc / step), -half, half) * step
    o_ref[...] = q.astype(o_ref.dtype)


def block_diag_mm_adc(
    blocks: jnp.ndarray, x: jnp.ndarray, bits: int, full_scale: float
) -> jnp.ndarray:
    """Quantized block-diagonal multiply (matches ``ref.adc_quantize`` of
    ``ref.block_diag_mm``)."""
    nb, b, _ = blocks.shape
    batch, n = x.shape
    return pl.pallas_call(
        functools.partial(
            _block_diag_adc_kernel, bits=bits, full_scale=full_scale
        ),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((batch, b), lambda k: (0, k)),
            pl.BlockSpec((1, b, b), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, b), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=INTERPRET,
    )(x, blocks)
