"""Pure-jnp reference oracle for the Monarch / block-diagonal kernels.

This module is the *correctness contract* for the Pallas kernels in
``monarch.py`` and for the Rust-side reimplementation: every layout and
index convention used anywhere in the repo is defined here, once.

Conventions (shared with ``rust/src/monarch/``):

* ``n = b * b``; a flat index ``i`` into a length-``n`` vector is split as
  ``i = i1 * b + i2``.
* The fixed Monarch permutation ``P`` swaps the two index digits:
  ``(P x)[i2 * b + i1] = x[i1 * b + i2]`` — i.e. transpose of the
  row-major ``(b, b)`` view.
* ``L`` and ``R`` are stored as ``(b, b, b)`` arrays of ``b`` dense
  ``b x b`` blocks: ``L[a]`` is block ``a`` of the left factor, ``R[k]``
  block ``k`` of the right factor.
* The Monarch operator is ``M = P @ diag(L) @ P @ diag(R) @ P`` and
  satisfies the rank-1 slice identity::

      M[(d, a), (c, k)] = L[a][d, k] * R[k][a, c]

  which is what the D2S projection exploits.
"""

from __future__ import annotations

import jax.numpy as jnp


def perm(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """Apply the stride permutation P to the last axis of ``x``.

    ``x[..., i1*b + i2] -> out[..., i2*b + i1]``.
    """
    shape = x.shape
    n = shape[-1]
    assert n == b * b, f"last dim {n} != b^2 ({b}^2)"
    y = x.reshape(*shape[:-1], b, b)
    y = jnp.swapaxes(y, -1, -2)
    return y.reshape(*shape)


def block_diag_mm(blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Multiply a block-diagonal matrix by batched vectors.

    ``blocks``: ``(nb, b, b)`` — block ``k`` acts on segment ``k``.
    ``x``: ``(..., nb * b)`` batched input.
    Returns ``y`` with
    ``y[..., k*b + d] = sum_c blocks[k, d, c] * x[..., k*b + c]``.
    """
    nb, b, b2 = blocks.shape
    assert b == b2
    xs = x.reshape(*x.shape[:-1], nb, b)
    ys = jnp.einsum("kdc,...kc->...kd", blocks, xs)
    return ys.reshape(*x.shape)


def block_diag_dense(blocks: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense ``(nb*b, nb*b)`` matrix of a block-diagonal."""
    nb, b, _ = blocks.shape
    n = nb * b
    out = jnp.zeros((n, n), blocks.dtype)
    for k in range(nb):
        out = out.at[k * b : (k + 1) * b, k * b : (k + 1) * b].set(blocks[k])
    return out


def monarch_apply(L: jnp.ndarray, R: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Apply ``M = P L P R P`` to batched vectors ``x`` of length ``n = b^2``."""
    b = L.shape[0]
    u = perm(x, b)
    v = block_diag_mm(R, u)
    w = perm(v, b)
    z = block_diag_mm(L, w)
    return perm(z, b)


def monarch_dense(L: jnp.ndarray, R: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense ``n x n`` Monarch matrix via the slice identity.

    ``M[(d, a), (c, k)] = L[a][d, k] * R[k][a, c]``.
    """
    b = L.shape[0]
    # m4[d, a, c, k] = L[a, d, k] * R[k, a, c]
    m4 = jnp.einsum("adk,kac->dack", L, R)
    return m4.reshape(b * b, b * b)


def monarch_mm(L: jnp.ndarray, R: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Batched matrix form: rows of ``x`` are independent vectors."""
    return monarch_apply(L, R, x)


def adc_quantize(y: jnp.ndarray, bits: int, full_scale: float) -> jnp.ndarray:
    """Emulate a SAR ADC readout: uniform mid-tread quantization to
    ``bits`` bits over ``[-full_scale, full_scale]``."""
    levels = (1 << bits) - 1
    step = 2.0 * full_scale / levels
    half = levels // 2
    q = jnp.clip(jnp.round(y / step), -half, half)
    return q * step
