"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for the Rust
coordinator (L3).

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Python runs ONCE, at build time (``make artifacts``); the Rust binary is
self-contained afterwards. Each artifact is listed in
``artifacts/manifest.json`` with its input/output shapes so the runtime
can validate feeds.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

# NOTE: jax >= 0.5 hoists large closed-over constants into HLO
# *parameters* instead of baking them into the module. Model artifacts
# therefore take their weights as explicit leading parameters, and the
# weight values are dumped to a `.weights.bin` sidecar (flat f32, leaf
# order) that the Rust runtime feeds back at execution time.

from . import model as m
from .kernels import monarch as mk

SEED = 2025


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_artifact(name, fn, example_args, out_dir, meta=None):
    """Lower ``fn`` at ``example_args`` and write ``<name>.hlo.txt``."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    entry = {
        "name": name,
        "file": fname,
        "inputs": [_spec_of(a) for a in example_args],
        "outputs": [_spec_of(o) for o in outs],
        "meta": meta or {},
    }
    print(f"  {fname}: {len(text)} chars")
    return entry


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    f32 = jnp.float32

    # --- L1 kernel artifacts: factors fed at runtime by the Rust D2S path.
    spec = jax.ShapeDtypeStruct
    entries.append(
        lower_artifact(
            "block_diag_b8",
            mk.block_diag_mm,
            (spec((8, 8, 8), f32), spec((4, 64), f32)),
            out_dir,
            {"kind": "block_diag", "b": 8, "nb": 8, "batch": 4},
        )
    )
    entries.append(
        lower_artifact(
            "monarch_mvm_n64",
            mk.monarch_mm,
            (spec((8, 8, 8), f32), spec((8, 8, 8), f32), spec((8, 64), f32)),
            out_dir,
            {"kind": "monarch_mvm", "n": 64, "b": 8, "batch": 8},
        )
    )
    entries.append(
        lower_artifact(
            "monarch_mvm_n1024",
            mk.monarch_mm,
            (
                spec((32, 32, 32), f32),
                spec((32, 32, 32), f32),
                spec((4, 1024), f32),
            ),
            out_dir,
            {"kind": "monarch_mvm", "n": 1024, "b": 32, "batch": 4},
        )
    )
    entries.append(
        lower_artifact(
            "monarch_mvm_lanes_n64",
            lambda L, R, x: mk.monarch_mm_lanes(L, R, x, lanes=4),
            (spec((8, 8, 8), f32), spec((8, 8, 8), f32), spec((8, 64), f32)),
            out_dir,
            {"kind": "monarch_mvm_lanes", "n": 64, "b": 8, "lanes": 4, "batch": 8},
        )
    )
    entries.append(
        lower_artifact(
            "block_diag_adc_b8",
            lambda w, x: mk.block_diag_mm_adc(w, x, bits=5, full_scale=8.0),
            (spec((8, 8, 8), f32), spec((4, 64), f32)),
            out_dir,
            {"kind": "block_diag_adc", "b": 8, "bits": 5, "full_scale": 8.0},
        )
    )

    # --- L2 model artifacts: weights as explicit leading parameters with
    # a binary sidecar (see module note), dynamic inputs trailing.
    cfg = m.ModelConfig(d_model=64, n_heads=4, n_layers=2, vocab=256, seq=32)
    params = jax.tree.map(jnp.asarray, m.init_params(cfg, seed=SEED))
    leaves, treedef = jax.tree.flatten(params)
    weight_specs = [spec(l.shape, l.dtype) for l in leaves]
    weights_file = "tiny_lm.weights.bin"
    with open(os.path.join(out_dir, weights_file), "wb") as f:
        for l in leaves:
            f.write(np.asarray(l, np.float32).tobytes())

    layer_leaves, layer_treedef = jax.tree.flatten(params["layers"][0])
    layer_weight_specs = [spec(l.shape, l.dtype) for l in layer_leaves]
    layer_weights_file = "monarch_layer_n64.weights.bin"
    with open(os.path.join(out_dir, layer_weights_file), "wb") as f:
        for l in layer_leaves:
            f.write(np.asarray(l, np.float32).tobytes())

    def layer_fwd(*args):
        *ws, x = args
        layer = jax.tree.unflatten(layer_treedef, ws)
        return m.encoder_layer(layer, x, cfg, causal=False)

    entries.append(
        lower_artifact(
            "monarch_layer_n64",
            layer_fwd,
            (*layer_weight_specs, spec((2, 16, 64), f32)),
            out_dir,
            {
                "kind": "encoder_layer",
                "d_model": 64,
                "seq": 16,
                "batch": 2,
                "weights_file": layer_weights_file,
                "n_weights": len(layer_leaves),
            },
        )
    )

    def lm_fwd_flat(*args):
        *ws, tokens = args
        p = jax.tree.unflatten(treedef, ws)
        return m.lm_forward(p, tokens, cfg)

    for batch in (1, 4, 8):
        entries.append(
            lower_artifact(
                f"tiny_lm_b{batch}",
                lm_fwd_flat,
                (*weight_specs, spec((batch, cfg.seq), jnp.int32)),
                out_dir,
                {
                    "kind": "tiny_lm",
                    "batch": batch,
                    "seq": cfg.seq,
                    "vocab": cfg.vocab,
                    "d_model": cfg.d_model,
                    "n_layers": cfg.n_layers,
                    "n_heads": cfg.n_heads,
                    "seed": SEED,
                    "weights_file": weights_file,
                    "n_weights": len(leaves),
                },
            )
        )

    # Golden outputs for runtime validation (tiny, deterministic).
    rng = np.random.default_rng(7)
    tok = rng.integers(0, cfg.vocab, size=(1, cfg.seq), dtype=np.int32)
    logits = np.asarray(m.lm_forward(params, jnp.asarray(tok), cfg))
    golden = {
        "tokens": tok.tolist(),
        "logits_sum": float(logits.sum()),
        "logits_first8": [float(v) for v in logits.reshape(-1)[:8]],
    }
    with open(os.path.join(out_dir, "tiny_lm_golden.json"), "w") as f:
        json.dump(golden, f, indent=1)

    manifest = {"version": 1, "seed": SEED, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
