"""Layer-2 JAX model: Monarch-sparse transformer blocks and a tiny Monarch LM.

Every *parameterized* matmul (Q/K/V/O projections, FFN up/down) is a
Monarch operator executed by the Layer-1 Pallas kernels
(``kernels.monarch``); the *non-parameterized* matmuls (attention scores,
attention-weighted values) stay dense, exactly as in the paper (§III-A,
Fig. 2b: Para-Matmul vs NonPara-Matmul).

Rectangular FFN matrices are partitioned into square ``d x d`` tiles, each
tile an independent Monarch factor pair — the same square-tile
partitioning used by ``rust/src/monarch/rect.rs`` and by the DenseMap
packing ("partitions of a single large matrix", §III-B2).

This module is build-time only: ``aot.py`` lowers the functions below to
HLO text once; the Rust coordinator executes the artifacts via PJRT.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import monarch as mk
from . import d2s


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-LM configuration; ``d_model`` must be a perfect square."""

    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff_mult: int = 4
    vocab: int = 256
    seq: int = 32

    @property
    def b(self) -> int:
        b = int(round(math.sqrt(self.d_model)))
        assert b * b == self.d_model, "d_model must be a perfect square"
        return b

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _init_monarch(rng: np.random.Generator, b: int, scale: float):
    """Random Monarch factor pair with dense-equivalent fan-in scaling.

    Each entry of the dense-equivalent ``M`` is a product of two factor
    entries, so factor entries are drawn with std ``sqrt(scale_M)`` to give
    the dense matrix variance ``scale_M^2 / b`` per entry * b terms... we
    simply draw both factors with std ``(scale / b) ** 0.5`` so that
    ``Var(M_ij) = scale^2 / b^2 * b = scale^2 / b`` — the usual 1/fan-in
    decay for n = b^2.
    """
    std = math.sqrt(scale / b)
    return {
        "L": rng.standard_normal((b, b, b)).astype(np.float32) * std,
        "R": rng.standard_normal((b, b, b)).astype(np.float32) * std,
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialize all weights of the tiny Monarch LM as a pytree."""
    rng = np.random.default_rng(seed)
    b = cfg.b
    d = cfg.d_model

    def ln():
        return {
            "g": np.ones((d,), np.float32),
            "b": np.zeros((d,), np.float32),
        }

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "wq": _init_monarch(rng, b, 1.0),
                "wk": _init_monarch(rng, b, 1.0),
                "wv": _init_monarch(rng, b, 1.0),
                "wo": _init_monarch(rng, b, 1.0),
                "ffn_up": [
                    _init_monarch(rng, b, 1.0) for _ in range(cfg.d_ff_mult)
                ],
                "ffn_down": [
                    _init_monarch(rng, b, 1.0 / cfg.d_ff_mult)
                    for _ in range(cfg.d_ff_mult)
                ],
                "ln1": ln(),
                "ln2": ln(),
            }
        )
    return {
        "embed": rng.standard_normal((cfg.vocab, d)).astype(np.float32) * 0.02,
        "pos": rng.standard_normal((cfg.seq, d)).astype(np.float32) * 0.02,
        "ln_f": ln(),
        "layers": layers,
    }


def params_from_dense(cfg: ModelConfig, dense_params: dict) -> dict:
    """D2S-transform a dense parameter pytree into Monarch form.

    ``dense_params`` mirrors ``init_params`` but with ``wq/wk/wv/wo`` as
    dense ``(d, d)`` arrays and ``ffn_up/ffn_down`` as ``(d_ff, d)`` /
    ``(d, d_ff)`` dense arrays; the projection of §III-A is applied per
    square tile.
    """
    d = cfg.d_model
    out = {
        "embed": dense_params["embed"],
        "pos": dense_params["pos"],
        "ln_f": dense_params["ln_f"],
        "layers": [],
    }
    for lp in dense_params["layers"]:
        q = {}
        for k in ("wq", "wk", "wv", "wo"):
            L, R = d2s.monarch_project(lp[k])
            q[k] = {"L": L, "R": R}
        up, down = [], []
        for t in range(cfg.d_ff_mult):
            L, R = d2s.monarch_project(lp["ffn_up"][t * d : (t + 1) * d, :])
            up.append({"L": L, "R": R})
            L, R = d2s.monarch_project(lp["ffn_down"][:, t * d : (t + 1) * d])
            down.append({"L": L, "R": R})
        q["ffn_up"] = up
        q["ffn_down"] = down
        q["ln1"] = lp["ln1"]
        q["ln2"] = lp["ln2"]
        out["layers"].append(q)
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def monarch_linear(p: dict, x2d: jnp.ndarray) -> jnp.ndarray:
    """Parameterized matmul in Monarch form: rows of ``x2d`` times ``M^T``
    (we store the operator so that ``y = M x`` per row)."""
    return mk.monarch_mm(p["L"], p["R"], x2d)


def layer_norm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def mha(layer: dict, x: jnp.ndarray, cfg: ModelConfig, causal: bool) -> jnp.ndarray:
    """Multi-head attention with Monarch Q/K/V/O projections.

    ``x``: (B, S, d). The scores/context matmuls are the paper's
    NonPara-Matmuls and stay dense.
    """
    B, S, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    x2 = x.reshape(B * S, d)

    def proj(p):
        return monarch_linear(p, x2).reshape(B, S, h, dh)

    q, k, v = proj(layer["wq"]), proj(layer["wk"]), proj(layer["wv"])
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bthd->bshd", attn, v).reshape(B * S, d)
    return monarch_linear(layer["wo"], ctx).reshape(B, S, d)


def ffn(layer: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Position-wise FFN with square-tile-partitioned Monarch up/down."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    hs = [monarch_linear(p, x2) for p in layer["ffn_up"]]
    h = gelu(jnp.concatenate(hs, axis=-1))
    out = jnp.zeros((B * S, d), x.dtype)
    for t, p in enumerate(layer["ffn_down"]):
        out = out + monarch_linear(p, h[:, t * d : (t + 1) * d])
    return out.reshape(B, S, d)


def encoder_layer(
    layer: dict, x: jnp.ndarray, cfg: ModelConfig, causal: bool = False
) -> jnp.ndarray:
    """Pre-norm transformer block with Monarch parameterized matmuls."""
    x = x + mha(layer, layer_norm(layer["ln1"], x), cfg, causal)
    x = x + ffn(layer, layer_norm(layer["ln2"], x), cfg)
    return x


def lm_forward(params: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Tiny Monarch LM: tokens (B, S) int32 -> logits (B, S, vocab).

    Decoder-only (causal); output projection is tied to the embedding
    (a NonPara-style dense matmul over activations, as the paper leaves
    embeddings untransformed).
    """
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :S]
    for layer in params["layers"]:
        x = encoder_layer(layer, x, cfg, causal=True)
    x = layer_norm(params["ln_f"], x)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"])


# ---------------------------------------------------------------------------
# Dense reference twins (for accuracy deltas and tests)
# ---------------------------------------------------------------------------


def dense_linear_from_monarch(p: dict, x2d: jnp.ndarray) -> jnp.ndarray:
    """Apply the densified Monarch operator (oracle for layer tests)."""
    from .kernels import ref

    M = ref.monarch_dense(jnp.asarray(p["L"]), jnp.asarray(p["R"]))
    return x2d @ M.T
