"""AOT artifact consistency: the manifest, HLO files, weight sidecars and
golden outputs must agree with each other and with the live model.

These tests validate an existing ``artifacts/`` build (they skip if
``make artifacts`` has not run) — catching drift between the Python
compile path and what the Rust runtime will load.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_files_exist(manifest):
    assert manifest["version"] == 1
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), f"missing {a['file']}"
        assert os.path.getsize(path) > 100
        if "weights_file" in a["meta"]:
            wpath = os.path.join(ART, a["meta"]["weights_file"])
            assert os.path.exists(wpath), f"missing {a['meta']['weights_file']}"


def test_weight_sidecar_sizes_match_specs(manifest):
    for a in manifest["artifacts"]:
        meta = a["meta"]
        if "weights_file" not in meta:
            continue
        n_weights = meta["n_weights"]
        expect = sum(
            int(np.prod(spec["shape"])) for spec in a["inputs"][:n_weights]
        )
        wpath = os.path.join(ART, meta["weights_file"])
        got = os.path.getsize(wpath) // 4
        assert got == expect, f"{a['name']}: sidecar {got} floats != {expect}"


def test_hlo_text_parses_as_hlo_module(manifest):
    # every artifact must contain an ENTRY computation (HLO text form)
    for a in manifest["artifacts"]:
        with open(os.path.join(ART, a["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, f"{a['name']}: no ENTRY computation"
        assert "->" in text


def test_golden_reproducible_from_sidecar():
    """Rebuilding the model from the sidecar weights reproduces the golden
    logits — the exact contract the Rust runtime relies on."""
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    art = next(a for a in manifest["artifacts"] if a["name"] == "tiny_lm_b1")
    meta = art["meta"]
    cfg = m.ModelConfig(
        d_model=meta["d_model"],
        n_heads=meta["n_heads"],
        n_layers=meta["n_layers"],
        vocab=meta["vocab"],
        seq=meta["seq"],
    )
    # reconstruct params from the sidecar in tree-flatten order
    template = m.init_params(cfg, seed=meta["seed"])
    leaves, treedef = jax.tree.flatten(template)
    raw = np.fromfile(
        os.path.join(ART, meta["weights_file"]), dtype=np.float32
    )
    out_leaves = []
    off = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        out_leaves.append(raw[off : off + n].reshape(leaf.shape))
        off += n
    assert off == raw.size
    params = jax.tree.unflatten(treedef, [jnp.asarray(l) for l in out_leaves])

    with open(os.path.join(ART, "tiny_lm_golden.json")) as f:
        golden = json.load(f)
    tok = jnp.asarray(np.array(golden["tokens"], np.int32))
    logits = np.asarray(m.lm_forward(params, tok, cfg))
    np.testing.assert_allclose(
        float(logits.sum()), golden["logits_sum"], rtol=1e-4
    )
    np.testing.assert_allclose(
        logits.reshape(-1)[:8], golden["logits_first8"], rtol=1e-4, atol=1e-5
    )


def test_sidecar_matches_fresh_init():
    """The sidecar must equal init_params(seed) — determinism contract."""
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    art = next(a for a in manifest["artifacts"] if a["name"] == "tiny_lm_b1")
    meta = art["meta"]
    cfg = m.ModelConfig(
        d_model=meta["d_model"],
        n_heads=meta["n_heads"],
        n_layers=meta["n_layers"],
        vocab=meta["vocab"],
        seq=meta["seq"],
    )
    leaves, _ = jax.tree.flatten(m.init_params(cfg, seed=meta["seed"]))
    raw = np.fromfile(os.path.join(ART, meta["weights_file"]), dtype=np.float32)
    fresh = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves])
    np.testing.assert_allclose(raw, fresh, rtol=1e-6)
