"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps block size, block count, batch and dtype; fixed-seed
numpy cases pin the exact layouts the AOT artifacts use.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import monarch as mk
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rnd(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# block_diag_mm
# ---------------------------------------------------------------------------


@given(
    b=st.sampled_from([1, 2, 4, 8, 16]),
    nb=st.integers(1, 12),
    batch=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_diag_matches_ref(b, nb, batch, seed):
    rng = np.random.default_rng(seed)
    blocks = rnd(rng, nb, b, b)
    x = rnd(rng, batch, nb * b)
    got = mk.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x))
    want = ref.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    b=st.sampled_from([2, 4, 8]),
    arrays=st.integers(1, 4),
    lanes=st.sampled_from([1, 2, 4]),
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_diag_lanes_matches_ref(b, arrays, lanes, batch, seed):
    """DenseMap lane-sequential kernel is numerically identical."""
    rng = np.random.default_rng(seed)
    nb = arrays * lanes
    blocks = rnd(rng, nb, b, b)
    x = rnd(rng, batch, nb * b)
    got = mk.block_diag_mm_lanes(jnp.asarray(blocks), jnp.asarray(x), lanes)
    want = ref.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_block_diag_identity_blocks():
    """Identity blocks pass the input through unchanged."""
    b, nb, batch = 4, 3, 2
    blocks = np.stack([np.eye(b, dtype=np.float32)] * nb)
    x = np.arange(batch * nb * b, dtype=np.float32).reshape(batch, nb * b)
    got = mk.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x))
    np.testing.assert_allclose(got, x, rtol=1e-6)


def test_block_diag_dense_equivalence():
    """Kernel output equals x @ dense(blockdiag)^T."""
    rng = np.random.default_rng(0)
    blocks = rnd(rng, 4, 4, 4)
    x = rnd(rng, 3, 16)
    dense = ref.block_diag_dense(jnp.asarray(blocks))
    want = x @ np.asarray(dense).T
    got = mk.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_diag_dtypes(dtype):
    rng = np.random.default_rng(1)
    blocks = jnp.asarray(rnd(rng, 4, 8, 8)).astype(dtype)
    x = jnp.asarray(rnd(rng, 2, 32)).astype(dtype)
    got = mk.block_diag_mm(blocks, x)
    want = ref.block_diag_mm(blocks, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# ---------------------------------------------------------------------------
# monarch_mm
# ---------------------------------------------------------------------------


@given(
    b=st.sampled_from([2, 3, 4, 8]),
    batch=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_monarch_matches_ref(b, batch, seed):
    rng = np.random.default_rng(seed)
    L, R = rnd(rng, b, b, b), rnd(rng, b, b, b)
    x = rnd(rng, batch, b * b)
    got = mk.monarch_mm(jnp.asarray(L), jnp.asarray(R), jnp.asarray(x))
    want = ref.monarch_apply(jnp.asarray(L), jnp.asarray(R), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(b=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_monarch_matches_dense_materialization(b, seed):
    """Kernel == multiply by the densified M (slice-identity check)."""
    rng = np.random.default_rng(seed)
    L, R = rnd(rng, b, b, b), rnd(rng, b, b, b)
    x = rnd(rng, 3, b * b)
    M = ref.monarch_dense(jnp.asarray(L), jnp.asarray(R))
    want = x @ np.asarray(M).T
    got = mk.monarch_mm(jnp.asarray(L), jnp.asarray(R), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    b=st.sampled_from([2, 4, 8]),
    lanes=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_monarch_lanes_matches_plain(b, lanes, seed):
    if b % lanes != 0:
        return
    rng = np.random.default_rng(seed)
    L, R = rnd(rng, b, b, b), rnd(rng, b, b, b)
    x = rnd(rng, 2, b * b)
    got = mk.monarch_mm_lanes(
        jnp.asarray(L), jnp.asarray(R), jnp.asarray(x), lanes
    )
    want = mk.monarch_mm(jnp.asarray(L), jnp.asarray(R), jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_perm_involution():
    """P is an involution: P(P(x)) == x."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rnd(rng, 5, 64))
    np.testing.assert_array_equal(ref.perm(ref.perm(x, 8), 8), x)


def test_monarch_linearity():
    """M(a x + b y) == a M(x) + b M(y)."""
    rng = np.random.default_rng(4)
    b = 4
    L, R = rnd(rng, b, b, b), rnd(rng, b, b, b)
    x, y = rnd(rng, 1, 16), rnd(rng, 1, 16)
    f = lambda v: np.asarray(
        mk.monarch_mm(jnp.asarray(L), jnp.asarray(R), jnp.asarray(v))
    )
    np.testing.assert_allclose(
        f(2.0 * x - 3.0 * y), 2.0 * f(x) - 3.0 * f(y), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# ADC quantized kernel
# ---------------------------------------------------------------------------


@given(
    bits=st.integers(3, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_adc_kernel_matches_ref_quantizer(bits, seed):
    rng = np.random.default_rng(seed)
    blocks = rnd(rng, 4, 4, 4)
    x = rnd(rng, 2, 16)
    fs = 8.0
    got = mk.block_diag_mm_adc(jnp.asarray(blocks), jnp.asarray(x), bits, fs)
    want = ref.adc_quantize(
        ref.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x)), bits, fs
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_adc_error_decreases_with_bits():
    """More ADC bits -> lower quantization error (monotone trend)."""
    rng = np.random.default_rng(11)
    blocks = rnd(rng, 8, 8, 8)
    x = rnd(rng, 4, 64)
    exact = np.asarray(ref.block_diag_mm(jnp.asarray(blocks), jnp.asarray(x)))
    errs = []
    for bits in (3, 5, 8):
        q = np.asarray(
            mk.block_diag_mm_adc(jnp.asarray(blocks), jnp.asarray(x), bits, 16.0)
        )
        errs.append(np.abs(q - exact).mean())
    assert errs[0] > errs[1] > errs[2]
