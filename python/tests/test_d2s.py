"""D2S projection tests (paper §III-A): exact recovery on true Monarch
matrices, optimality vs perturbations, error monotonicity."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import d2s
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(b=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_exact_recovery_of_monarch_matrices(b, seed):
    """Projecting a matrix already in the Monarch class recovers it."""
    L, R = d2s.random_monarch(b, seed)
    M = d2s.monarch_dense_np(L, R)
    L2, R2 = d2s.monarch_project(M)
    M2 = d2s.monarch_dense_np(L2, R2)
    np.testing.assert_allclose(M2, M, rtol=1e-4, atol=1e-4)


def test_dense_np_matches_jnp_reference():
    import jax.numpy as jnp

    L, R = d2s.random_monarch(4, 3)
    got = d2s.monarch_dense_np(L, R)
    want = np.asarray(ref.monarch_dense(jnp.asarray(L), jnp.asarray(R)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(b=st.sampled_from([3, 4]), seed=st.integers(0, 2**31 - 1))
def test_projection_error_bounded_by_input_norm(b, seed):
    """||W - proj(W)||_F <= ||W||_F (projection never worse than zero)."""
    rng = np.random.default_rng(seed)
    W = rng.standard_normal((b * b, b * b)).astype(np.float32)
    L, R = d2s.monarch_project(W)
    M = d2s.monarch_dense_np(L, R)
    assert np.linalg.norm(W - M) <= np.linalg.norm(W) + 1e-4


def test_projection_optimal_per_slice():
    """Each projected slice is the best rank-1 approx: residual slice is
    orthogonal-ish — check error equals sum of discarded singular values."""
    rng = np.random.default_rng(0)
    b = 4
    W = rng.standard_normal((b * b, b * b)).astype(np.float64)
    L, R = d2s.monarch_project(W)
    M = d2s.monarch_dense_np(L, R)
    # Expected squared error = sum over slices of (sum of s_i^2 for i >= 1)
    w4 = W.reshape(b, b, b, b).transpose(1, 3, 0, 2).reshape(b * b, b, b)
    s = np.linalg.svd(w4, compute_uv=False)
    expect = np.sum(s[:, 1:] ** 2)
    got = np.linalg.norm(W - M) ** 2
    np.testing.assert_allclose(got, expect, rtol=1e-8)


def test_error_decreases_with_structure():
    """A near-Monarch matrix projects with smaller error than iid noise."""
    rng = np.random.default_rng(1)
    b = 8
    L, R = d2s.random_monarch(b, 5)
    M = d2s.monarch_dense_np(L, R)
    noise = rng.standard_normal(M.shape).astype(np.float32)
    near = M + 0.05 * noise
    assert d2s.projection_error(near) < d2s.projection_error(noise)


def test_low_rank_slices_project_exactly():
    """A matrix whose slices are rank-1 but built directly (not via L,R)
    is also recovered exactly."""
    rng = np.random.default_rng(2)
    b = 4
    u = rng.standard_normal((b, b, b)).astype(np.float64)
    v = rng.standard_normal((b, b, b)).astype(np.float64)
    # slices[a,k] = outer(u[a,k], v[a,k])
    m4 = np.einsum("akd,akc->dack", u.transpose(0, 2, 1), v.transpose(0, 2, 1))
    W = m4.reshape(b * b, b * b)
    assert d2s.projection_error(W) < 1e-10
