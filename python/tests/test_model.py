"""L2 model tests: shapes, Monarch-vs-densified equivalence, D2S pipeline
through a whole layer, causal masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import d2s
from compile import model as m
from compile.kernels import ref

CFG = m.ModelConfig(d_model=64, n_heads=4, n_layers=2, vocab=64, seq=16)


@pytest.fixture(scope="module")
def params():
    return jax.tree.map(jnp.asarray, m.init_params(CFG, seed=0))


def test_param_shapes(params):
    b = CFG.b
    assert params["embed"].shape == (CFG.vocab, CFG.d_model)
    lay = params["layers"][0]
    for k in ("wq", "wk", "wv", "wo"):
        assert lay[k]["L"].shape == (b, b, b)
        assert lay[k]["R"].shape == (b, b, b)
    assert len(lay["ffn_up"]) == CFG.d_ff_mult
    assert len(lay["ffn_down"]) == CFG.d_ff_mult


def test_monarch_linear_matches_densified(params):
    """The layer's parameterized matmul == multiply by densified M."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, CFG.d_model)).astype(np.float32))
    p = params["layers"][0]["wq"]
    got = m.monarch_linear(p, x)
    want = m.dense_linear_from_monarch(p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_encoder_layer_shape(params):
    x = jnp.zeros((2, CFG.seq, CFG.d_model), jnp.float32)
    y = m.encoder_layer(params["layers"][0], x, CFG)
    assert y.shape == x.shape


def test_lm_forward_shape_and_finite(params):
    tok = jnp.zeros((3, CFG.seq), jnp.int32)
    logits = m.lm_forward(params, tok, CFG)
    assert logits.shape == (3, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_lm_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(1)
    tok = rng.integers(0, CFG.vocab, size=(1, CFG.seq)).astype(np.int32)
    tok2 = tok.copy()
    tok2[0, -1] = (tok2[0, -1] + 1) % CFG.vocab
    l1 = m.lm_forward(params, jnp.asarray(tok), CFG)
    l2 = m.lm_forward(params, jnp.asarray(tok2), CFG)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-4, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_lm_batch_consistency(params):
    """Each batch row is independent."""
    rng = np.random.default_rng(2)
    tok = rng.integers(0, CFG.vocab, size=(4, CFG.seq)).astype(np.int32)
    full = m.lm_forward(params, jnp.asarray(tok), CFG)
    row = m.lm_forward(params, jnp.asarray(tok[2:3]), CFG)
    np.testing.assert_allclose(full[2:3], row, rtol=1e-4, atol=1e-4)


def test_ffn_tile_partition_matches_dense_concat(params):
    """FFN up tiles == one dense (d_ff x d) matmul of stacked densified tiles."""
    lay = params["layers"][0]
    d = CFG.d_model
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((7, d)).astype(np.float32))
    tiles = [
        np.asarray(ref.monarch_dense(p["L"], p["R"])) for p in lay["ffn_up"]
    ]
    W1 = np.concatenate(tiles, axis=0)  # (d_ff, d)
    want = np.asarray(x) @ W1.T
    got = jnp.concatenate([m.monarch_linear(p, x) for p in lay["ffn_up"]], -1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_d2s_layer_pipeline_accuracy():
    """params_from_dense: a dense layer D2S'd to Monarch keeps the layer
    output close when the dense weights are near the Monarch class."""
    cfg = m.ModelConfig(d_model=16, n_heads=2, n_layers=1, vocab=32, seq=8)
    d, b = cfg.d_model, cfg.b
    rng = np.random.default_rng(4)

    def near_monarch():
        L, R = d2s.random_monarch(b, int(rng.integers(1 << 30)))
        M = d2s.monarch_dense_np(L / b, R)  # scaled for stability
        return M + 0.01 * rng.standard_normal(M.shape).astype(np.float32)

    dense_layer = {
        "wq": near_monarch(),
        "wk": near_monarch(),
        "wv": near_monarch(),
        "wo": near_monarch(),
        "ffn_up": np.concatenate(
            [near_monarch() for _ in range(cfg.d_ff_mult)], axis=0
        ),
        "ffn_down": np.concatenate(
            [near_monarch() for _ in range(cfg.d_ff_mult)], axis=1
        ),
        "ln1": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
        "ln2": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
    }
    dense_params = {
        "embed": rng.standard_normal((cfg.vocab, d)).astype(np.float32) * 0.1,
        "pos": rng.standard_normal((cfg.seq, d)).astype(np.float32) * 0.1,
        "ln_f": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
        "layers": [dense_layer],
    }
    sparse = jax.tree.map(jnp.asarray, m.params_from_dense(cfg, dense_params))

    x = jnp.asarray(rng.standard_normal((1, cfg.seq, d)).astype(np.float32))
    y_sparse = m.encoder_layer(sparse["layers"][0], x, cfg)

    # Dense reference layer using the original dense weights.
    def dense_layer_fwd(x):
        x2 = x.reshape(-1, d)

        def lin(W, v):
            return v @ jnp.asarray(W).T

        h = m.layer_norm(sparse["layers"][0]["ln1"], x)
        h2 = h.reshape(-1, d)
        q = lin(dense_layer["wq"], h2).reshape(1, cfg.seq, cfg.n_heads, -1)
        k = lin(dense_layer["wk"], h2).reshape(1, cfg.seq, cfg.n_heads, -1)
        v = lin(dense_layer["wv"], h2).reshape(1, cfg.seq, cfg.n_heads, -1)
        import math

        sc = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(cfg.d_head)
        at = jax.nn.softmax(sc, -1)
        ctx = jnp.einsum("bhst,bthd->bshd", at, v).reshape(-1, d)
        x = x + lin(dense_layer["wo"], ctx).reshape(1, cfg.seq, d)
        h = m.layer_norm(sparse["layers"][0]["ln2"], x).reshape(-1, d)
        up = m.gelu(lin(dense_layer["ffn_up"], h))
        down = lin(dense_layer["ffn_down"], up)
        return x + down.reshape(1, cfg.seq, d)

    y_dense = dense_layer_fwd(x)
    rel = float(
        jnp.linalg.norm(y_sparse - y_dense) / jnp.linalg.norm(y_dense)
    )
    assert rel < 0.05, f"D2S layer relative error too high: {rel}"
