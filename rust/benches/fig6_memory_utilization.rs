//! Bench + reproduction of paper Fig. 6: CIM array counts (6a) and
//! array-wise utilization (6b) for Linear / SparseMap / DenseMap.
//!
//! Paper targets: SparseMap ~50% fewer arrays than Linear; DenseMap ~87%
//! fewer than Linear and >73% fewer than SparseMap; utilization Linear
//! 100%, SparseMap ~20.4%, DenseMap ~78.8%.
//!
//! `cargo bench --bench fig6_memory_utilization`

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::stats::{fig6_stats, mean_array_reduction, mean_utilization};
use monarch_cim::mapping::{map_model, Strategy};
use monarch_cim::model::ModelConfig;
use monarch_cim::report;
use monarch_cim::util::bench::{section, Bencher};

fn main() {
    let params = CimParams::default();

    section("Fig. 6 — arrays & utilization (reproduction)");
    report::fig6(&params).print();

    let stats = fig6_stats(&params);
    println!(
        "array reduction: SparseMap vs Linear {:.0}% (paper ~50%); DenseMap vs Linear {:.0}% (paper ~87%); DenseMap vs SparseMap {:.0}% (paper >73%)",
        100.0 * mean_array_reduction(&stats, Strategy::SparseMap, Strategy::Linear),
        100.0 * mean_array_reduction(&stats, Strategy::DenseMap, Strategy::Linear),
        100.0 * mean_array_reduction(&stats, Strategy::DenseMap, Strategy::SparseMap),
    );
    println!(
        "utilization: Linear {:.0}% | SparseMap {:.1}% (paper 20.4%) | DenseMap {:.1}% (paper 78.8%)",
        100.0 * mean_utilization(&stats, Strategy::Linear),
        100.0 * mean_utilization(&stats, Strategy::SparseMap),
        100.0 * mean_utilization(&stats, Strategy::DenseMap),
    );

    section("mapping engine throughput");
    let mut b = Bencher::new();
    for strategy in Strategy::all() {
        for cfg in [ModelConfig::bert_large(), ModelConfig::bart_large()] {
            b.bench(
                &format!("map/{}/{}", strategy.name(), cfg.name),
                || std::hint::black_box(map_model(&cfg, &params, strategy)),
            );
        }
    }
}
