//! Bench + reproduction of the §IV-C ADC/DAC resolution claim: lowering
//! resolution from 8 b (Linear) to 3 b (DenseMap) cuts conversion latency
//! and energy by ~2.67x (= 8/3, linear SAR scaling).
//!
//! Also sweeps the quantization *accuracy* side with the functional
//! crossbar, connecting the resolution choice to numerical error.
//!
//! `cargo bench --bench adc_resolution`

use monarch_cim::cim::crossbar::Crossbar;
use monarch_cim::cim::{adc, CimParams};
use monarch_cim::report;
use monarch_cim::tensor::Matrix;
use monarch_cim::util::bench::{section, Bencher};
use monarch_cim::util::rng::Pcg32;

fn main() {
    let params = CimParams::default();

    section("§IV-C — ADC resolution scaling (reproduction)");
    report::adc_resolution(&params).print();
    println!(
        "8b -> 3b: latency {:.2}x, energy {:.2}x (paper: ~2.67x)",
        adc::t_conversion_ns(&params, 8) / adc::t_conversion_ns(&params, 3),
        adc::e_conversion_nj(&params, 8) / adc::e_conversion_nj(&params, 3),
    );

    section("quantization accuracy at each operating point");
    let mut rng = Pcg32::new(30);
    let b = 32;
    let w = Matrix::randn(b, b, &mut rng).scale(1.0 / (b as f32).sqrt());
    let mut xb = Crossbar::new(b);
    xb.program_block(0, 0, &w.transpose());
    let x = rng.normal_vec(b);
    let rows: Vec<usize> = (0..b).collect();
    let exact = xb.mvm_pass(&x, &rows);
    for bits in [8u32, 5, 3] {
        let q = xb.mvm_pass_quantized(&x, &rows, bits, 4.0);
        let err: f32 = exact
            .iter()
            .zip(&q)
            .map(|(a, c)| (a - c).abs())
            .sum::<f32>()
            / b as f32;
        println!("  {bits}b readout: mean |error| = {err:.4} per output");
    }

    section("conversion-model throughput");
    let mut bench = Bencher::new();
    bench.bench("required_bits sweep 1..=1024", || {
        for rows in 1..=1024usize {
            std::hint::black_box(adc::required_bits(&params, rows));
        }
    });
    bench.bench("crossbar mvm_pass 256x256 (32 active rows)", || {
        let mut big = Crossbar::new(256);
        big.program_block(0, 0, &Matrix::eye(32));
        std::hint::black_box(big.mvm_pass(&vec![1.0; 256], &(0..32).collect::<Vec<_>>()))
    });
}
