//! Bench + reproduction of paper Fig. 8: BERT latency (8a) and energy
//! (8b) under varying ADC-sharing degrees (4 -> 32 ADCs per array).
//!
//! Paper targets: DenseMap wins at 4 ADCs/array (1.6x over Linear, 1.1x
//! over SparseMap); DenseMap flat beyond 8 ADCs/array; at 32 ADCs/array
//! SparseMap is best (3.57x over DenseMap, 1.6x over Linear).
//!
//! `cargo bench --bench fig8_adc_sharing`

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::report;
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::util::bench::{section, Bencher};

fn main() {
    section("Fig. 8 — ADC sharing DSE (reproduction, BERT)");
    report::fig8(&[1, 2, 4, 8, 16, 32]).print();

    let cfg = ModelConfig::bert_large();
    let lat = |s: Strategy, adcs: usize| {
        cost_report(&cfg, &CimParams::default().with_adcs_per_array(adcs), s).latency_ms()
    };
    println!(
        "@4 ADCs: DenseMap {:.2}x over Linear (paper 1.6x), {:.2}x over SparseMap (paper 1.1x)",
        lat(Strategy::Linear, 4) / lat(Strategy::DenseMap, 4),
        lat(Strategy::SparseMap, 4) / lat(Strategy::DenseMap, 4),
    );
    println!(
        "@32 ADCs: SparseMap {:.2}x over DenseMap (paper 3.57x), {:.2}x over Linear (paper 1.6x)",
        lat(Strategy::DenseMap, 32) / lat(Strategy::SparseMap, 32),
        lat(Strategy::Linear, 32) / lat(Strategy::SparseMap, 32),
    );
    println!(
        "DenseMap flatness: 8 -> 32 ADCs changes latency by {:.1}% (paper: no improvement)",
        100.0 * (lat(Strategy::DenseMap, 8) / lat(Strategy::DenseMap, 32) - 1.0)
    );

    section("DSE sweep throughput");
    let mut b = Bencher::new();
    b.bench("fig8 full sweep (5 points x 3 strategies)", || {
        for adcs in [1usize, 4, 8, 16, 32] {
            let p = CimParams::default().with_adcs_per_array(adcs);
            for s in Strategy::all() {
                std::hint::black_box(cost_report(&cfg, &p, s));
            }
        }
    });
}
