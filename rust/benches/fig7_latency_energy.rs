//! Bench + reproduction of paper Fig. 7: inference latency (7a) and
//! energy (7b) across GPU / Linear / SparseMap / DenseMap.
//!
//! Paper targets (geomean over BERT-large, BART-large, GPT-2-medium):
//! SparseMap 1.59x latency & 1.61x energy over Linear; DenseMap 1.73x &
//! 1.74x; Linear CIM ~16.2x faster than the RTX 3090 Ti on BERT and ~3
//! orders of magnitude more energy-efficient.
//!
//! `cargo bench --bench fig7_latency_energy`

use monarch_cim::cim::CimParams;
use monarch_cim::gpu::{gpu_cost, GpuParams};
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::report;
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::util::bench::{section, Bencher};
use monarch_cim::util::stats::geomean;

fn main() {
    let params = CimParams::default();
    let gpu = GpuParams::default();

    section("Fig. 7 — latency & energy (reproduction)");
    report::fig7(&params, &gpu).print();

    let mut sp = Vec::new();
    let mut de = Vec::new();
    for cfg in ModelConfig::paper_models() {
        let lin = cost_report(&cfg, &params, Strategy::Linear);
        sp.push(lin.latency_ms() / cost_report(&cfg, &params, Strategy::SparseMap).latency_ms());
        de.push(lin.latency_ms() / cost_report(&cfg, &params, Strategy::DenseMap).latency_ms());
    }
    println!(
        "geomean latency speedups: SparseMap {:.2}x (paper 1.59x), DenseMap {:.2}x (paper 1.73x)",
        geomean(&sp),
        geomean(&de)
    );
    let bert = ModelConfig::bert_large();
    let g = gpu_cost(&bert, &gpu);
    let lin = cost_report(&bert, &params, Strategy::Linear);
    println!(
        "BERT: Linear CIM vs GPU: {:.1}x faster (paper 16.2x), {:.0}x less energy (paper ~1000x)",
        g.total_ns / (lin.latency_ms() * 1e6),
        g.total_nj / (lin.energy_mj() * 1e6)
    );

    section("cost-model throughput");
    let mut b = Bencher::new();
    for strategy in Strategy::all() {
        b.bench(&format!("cost_report/bert/{}", strategy.name()), || {
            std::hint::black_box(cost_report(&bert, &params, strategy))
        });
    }
}
