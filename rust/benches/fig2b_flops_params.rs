//! Bench + reproduction of paper Fig. 2b: parameter and FLOP reduction
//! from the D2S transformation (BERT-large headline: ~8x params, ~5.7x
//! FLOPs, Para-Matmuls > 80% of FLOPs).
//!
//! `cargo bench --bench fig2b_flops_params`

use monarch_cim::model::{count_report, ModelConfig};
use monarch_cim::report;
use monarch_cim::util::bench::{section, Bencher};

fn main() {
    section("Fig. 2b — params & FLOPs reduction (reproduction)");
    report::fig2b().print();

    let r = count_report(&ModelConfig::bert_large());
    println!(
        "BERT-large (paper): params 8x -> measured {:.1}x (model) / {:.1}x (para); \
         FLOPs 5.7x -> measured {:.1}x; para share {:.0}% (paper >80%)",
        r.model_param_reduction(),
        r.para_param_reduction(),
        r.flops_reduction(),
        100.0 * r.para_flops_fraction()
    );

    section("accounting throughput");
    let mut b = Bencher::new();
    for cfg in ModelConfig::paper_models() {
        b.bench(&format!("count_report/{}", cfg.name), || {
            std::hint::black_box(count_report(&cfg))
        });
    }
    b.bench("graph build/bart-large", || {
        std::hint::black_box(monarch_cim::model::build_graph(
            &ModelConfig::bart_large(),
        ))
    });
}
