//! Bench: autoregressive decode throughput of the functional CIM chip
//! across the three mapping strategies, plus the modeled per-token
//! latency/energy the scheduler attributes to each (the paper's Fig. 7
//! quantities measured in their native regime — token-by-token decode
//! with a growing KV cache, instead of per-op matvecs).
//!
//! Reports host-wall-clock **tokens/sec** per strategy (the number the
//! compiled-plan replay optimizes) with a **bit-block vs index-replay**
//! comparison (the two pass-table encodings, DESIGN.md §6e — both are
//! bit-identical, so the delta is pure replay-loop speed), an **analog
//! mode overhead** check (DESIGN.md §6i — ideal `AnalogMode` must ride
//! the bare path within noise and decode bit-identically, asserted
//! un-timed; a noisy + ADC-capped chip prices the realism tax), plus a
//! batched sweep (B ∈ {1..8} concurrent streams through one DenseMap
//! chip via `BatchDecodeEngine::generate_batch` — the serving
//! amortization, both encodings measured per B) and a
//! **chunked-prefill sweep** (prompt lengths × chunk sizes through
//! `BatchDecodeEngine::step_chunks`, lanes = positions — the
//! time-to-first-token amortization) and a **speculative-decode sweep**
//! (K ∈ {1,2,4,8} × self-draft depths through `SpeculativeEngine`,
//! verify-as-chunk — accepted-tokens/round and modeled speedup vs plain
//! decode, cross-checked bit-identical), and a **sharded pipeline
//! sweep** (shards ∈ {1,2,4} × B ∈ {1,4,8} in-flight streams through
//! `BatchDecodeEngine::sharded` on an 8-layer tiny variant —
//! tokens/sec, modeled speedup_vs_1chip and bubble_fraction from the
//! per-stage timeline, cross-checked bit-identical to the single
//! chip), and writes machine-readable `BENCH_decode.json` /
//! `BENCH_prefill.json` / `BENCH_spec.json` / `BENCH_pipeline.json` so
//! the perf trajectory is trackable per commit.
//!
//! ```text
//! cargo bench --bench decode_throughput                      # writes all four JSON artifacts
//! cargo bench --bench decode_throughput -- --bench-json out.json --prefill-json pre.json --spec-json spec.json --pipeline-json pipe.json
//! BENCH_JSON=out.json BENCH_PREFILL_JSON=pre.json BENCH_SPEC_JSON=spec.json BENCH_PIPELINE_JSON=pipe.json ...  # env override
//! BENCH_QUICK=1 ...                                          # CI smoke mode
//! ```

use monarch_cim::cim::{AnalogMode, CimParams, PcmNoise};
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::sim::exec::ReplayMode;
use monarch_cim::sim::speculate::{self_draft_model, SpeculativeEngine};
use monarch_cim::util::bench::{section, write_json_artifact, Bencher};
use monarch_cim::util::json::{num, obj, s, Json};

const PROMPT: [i32; 4] = [11, 48, 85, 122];
const TOKENS: usize = 16;

/// Sweep records (`name -> row`) as a JSON object.
fn sweep_obj(records: &[(String, Json)]) -> Json {
    obj(records.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

fn main() {
    let cfg = ModelConfig::tiny();
    let params = CimParams::default();
    let mut b = Bencher::new();
    // each generate() runs prompt + generated forward passes
    let passes = (PROMPT.len() + TOKENS) as f64;
    let mut records: Vec<(String, Json)> = Vec::new();

    section("decode engine — functional-sim throughput (tiny model)");
    let mut reference = DecodeEngine::reference(DecodeModel::synth(cfg.clone(), 2025));
    let meas = b
        .bench("reference decode 16 tokens", || {
            std::hint::black_box(reference.generate(&PROMPT, TOKENS))
        })
        .clone();
    let ref_tps = passes / (meas.mean_ns * 1e-9);
    println!("  -> {ref_tps:.0} tokens/s (host wall-clock)");
    records.push((
        "Reference".to_string(),
        obj(vec![
            ("tokens_per_sec", num(ref_tps)),
            ("ns_per_token", num(meas.mean_ns / passes)),
        ]),
    ));

    for strategy in Strategy::all() {
        let mut eng = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            strategy,
        );
        let meas = b
            .bench(&format!("{} decode 16 tokens", strategy.name()), || {
                std::hint::black_box(eng.generate(&PROMPT, TOKENS))
            })
            .clone();
        let tps = passes / (meas.mean_ns * 1e-9);
        // same decode through the index-list pass encoding — outputs
        // are bit-identical, so the delta is pure replay-loop speed
        eng.set_replay_mode(ReplayMode::IndexList);
        let meas_idx = b
            .bench(
                &format!("{} decode 16 tokens (index replay)", strategy.name()),
                || std::hint::black_box(eng.generate(&PROMPT, TOKENS)),
            )
            .clone();
        eng.set_replay_mode(ReplayMode::BitBlock);
        let idx_tps = passes / (meas_idx.mean_ns * 1e-9);
        let arrays = eng.mapping().map(|mm| mm.arrays).unwrap_or(0);
        // one un-timed run for the modeled per-token cost breakdown
        let r = eng.generate(&PROMPT, TOKENS);
        let total = r.total();
        let n_tok = r.per_token.len().max(1) as f64;
        println!(
            "  -> {:.0} tokens/s wall ({:.2} µs/token) | modeled chip: {:.3} µs/token, {:.1} nJ/token ({} arrays)",
            tps,
            meas.mean_ns / passes / 1e3,
            total.latency.critical_ns() / n_tok / 1e3,
            total.energy.total_nj() / n_tok,
            arrays,
        );
        println!(
            "  -> last-token MHA share: {:.0} ns of {:.0} ns critical path (KV cache {} entries)",
            r.per_token.last().map(|c| c.latency.mha_ns).unwrap_or(0.0),
            r.per_token
                .last()
                .map(|c| c.latency.critical_ns())
                .unwrap_or(0.0),
            PROMPT.len() + TOKENS,
        );
        println!(
            "  -> replay encoding: bit-block {:.0} vs index {:.0} tokens/s ({:.2}x)",
            tps,
            idx_tps,
            tps / idx_tps.max(1e-12),
        );
        records.push((
            strategy.name().to_string(),
            obj(vec![
                ("tokens_per_sec", num(tps)),
                ("ns_per_token", num(meas.mean_ns / passes)),
                ("speedup_vs_reference", num(tps / ref_tps)),
                ("tokens_per_sec_index_replay", num(idx_tps)),
                ("bitblock_speedup_vs_index", num(tps / idx_tps.max(1e-12))),
                ("modeled_ns_per_token", num(total.latency.critical_ns() / n_tok)),
                ("modeled_nj_per_token", num(total.energy.total_nj() / n_tok)),
                ("arrays", num(arrays as f64)),
            ]),
        ));
    }

    section("analog mode overhead — exact vs ideal vs noisy (DenseMap)");
    // Analog realism (DESIGN.md §6i) corrupts cells at PROGRAMMING time;
    // the replay loop itself only changes when an ADC cap actually
    // bites. Ideal mode must therefore ride the bare path — within
    // noise on wall-clock, and bit-identical on output (asserted,
    // un-timed) — while a noisy + capped chip prices the realism tax.
    {
        let mut bare = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
        );
        let bare_m = b
            .bench("dense decode 16 tokens (bare)", || {
                std::hint::black_box(bare.generate(&PROMPT, TOKENS))
            })
            .clone();
        let ideal = AnalogMode::ideal();
        let mut ideal_eng = DecodeEngine::on_chip_analog(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
            Some(&ideal),
        );
        let ideal_m = b
            .bench("dense decode 16 tokens (ideal analog)", || {
                std::hint::black_box(ideal_eng.generate(&PROMPT, TOKENS))
            })
            .clone();
        let noisy = AnalogMode {
            noise: PcmNoise {
                write_sigma: 0.01,
                drift_nu: 0.05,
                drift_time_ratio: 1.0e4,
            },
            adc_bits: Some(3),
            seed: 7,
        };
        let mut noisy_eng = DecodeEngine::on_chip_analog(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
            Some(&noisy),
        );
        let noisy_m = b
            .bench("dense decode 16 tokens (noisy analog)", || {
                std::hint::black_box(noisy_eng.generate(&PROMPT, TOKENS))
            })
            .clone();
        // one un-timed round: ideal mode must not change a single token
        let rb = bare.generate(&PROMPT, TOKENS);
        let ri = ideal_eng.generate(&PROMPT, TOKENS);
        assert_eq!(
            rb.tokens, ri.tokens,
            "ideal analog mode must decode bit-identically to the bare path"
        );
        let bare_tps = passes / (bare_m.mean_ns * 1e-9);
        let ideal_tps = passes / (ideal_m.mean_ns * 1e-9);
        let noisy_tps = passes / (noisy_m.mean_ns * 1e-9);
        let ideal_pct = (ideal_m.mean_ns / bare_m.mean_ns - 1.0) * 100.0;
        let noisy_pct = (noisy_m.mean_ns / bare_m.mean_ns - 1.0) * 100.0;
        println!(
            "  -> bare {bare_tps:.0} / ideal {ideal_tps:.0} / noisy {noisy_tps:.0} tokens/s; ideal-mode overhead {ideal_pct:+.2}%, noisy {noisy_pct:+.2}% (outputs: ideal bit-identical)",
        );
        records.push((
            "analog".to_string(),
            obj(vec![
                ("tokens_per_sec_bare", num(bare_tps)),
                ("tokens_per_sec_ideal", num(ideal_tps)),
                ("tokens_per_sec_noisy", num(noisy_tps)),
                ("ideal_overhead_pct", num(ideal_pct)),
                ("noisy_overhead_pct", num(noisy_pct)),
            ]),
        ));
    }

    section("batched decode sweep — B concurrent streams, one DenseMap chip");
    let mut batched_records: Vec<(String, Json)> = Vec::new();
    let mut b1_tps = 0.0f64;
    for batch in 1usize..=8 {
        let mut eng = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
            batch,
        );
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|s| PROMPT.iter().map(|&t| (t + s as i32) % cfg.vocab as i32).collect())
            .collect();
        let meas = b
            .bench(&format!("dense batched decode B={batch}"), || {
                std::hint::black_box(eng.generate_batch(&prompts, TOKENS))
            })
            .clone();
        // every stream advances prompt+TOKENS positions per iteration
        let tps = batch as f64 * passes / (meas.mean_ns * 1e-9);
        // index-list pass encoding, same chip + prompts (bit-identical
        // logits; the delta is pure replay-loop speed)
        eng.set_replay_mode(ReplayMode::IndexList);
        let meas_idx = b
            .bench(&format!("dense batched decode B={batch} (index replay)"), || {
                std::hint::black_box(eng.generate_batch(&prompts, TOKENS))
            })
            .clone();
        eng.set_replay_mode(ReplayMode::BitBlock);
        let idx_tps = batch as f64 * passes / (meas_idx.mean_ns * 1e-9);
        if batch == 1 {
            b1_tps = tps;
        }
        println!(
            "  -> B={batch}: {:.0} tokens/s wall ({:.2} µs/token-step), {:.2}x vs B=1",
            tps,
            meas.mean_ns / passes / 1e3,
            tps / b1_tps.max(1e-12),
        );
        println!(
            "  -> B={batch}: bit-block {:.0} vs index {:.0} tokens/s ({:.2}x)",
            tps,
            idx_tps,
            tps / idx_tps.max(1e-12),
        );
        batched_records.push((
            format!("batch_{batch}"),
            obj(vec![
                ("batch", num(batch as f64)),
                ("tokens_per_sec", num(tps)),
                ("ns_per_token", num(meas.mean_ns / (batch as f64 * passes))),
                ("speedup_vs_b1", num(tps / b1_tps.max(1e-12))),
                ("tokens_per_sec_index_replay", num(idx_tps)),
                ("bitblock_speedup_vs_index", num(tps / idx_tps.max(1e-12))),
            ]),
        ));
    }

    section("chunked prefill sweep — C positions per replay, one DenseMap chip");
    // Prompt ingestion at chunk C walks the compiled pass tables S/C
    // times instead of S (lanes = positions); the sweep measures the
    // host-wall prefill tokens/sec and the speedup over token-by-token.
    let mut prefill_records: Vec<(String, Json)> = Vec::new();
    let mut eng = BatchDecodeEngine::on_chip(
        DecodeModel::synth(cfg.clone(), 2025),
        params.clone(),
        Strategy::DenseMap,
        1,
    );
    let passes_per_position = eng
        .mapping()
        .map(|mm| monarch_cim::scheduler::compile_plan(mm).total_passes())
        .unwrap_or(0);
    for &plen in &[8usize, 16, 32] {
        let prompt: Vec<i32> =
            (0..plen).map(|i| ((i * 37 + 11) % cfg.vocab) as i32).collect();
        let mut chunk1_tps = 0.0f64;
        for &chunk in &[1usize, 2, 4, 8, 16] {
            if chunk > plen {
                continue;
            }
            // modeled pipelined chunk latency (trace::prefill_chunk_cost):
            // row drives shared across the chunk's position lanes
            let (modeled_chunk_ns, modeled_serial_ns) = eng
                .mapping()
                .map(|mm| {
                    let pc = monarch_cim::sim::trace::prefill_chunk_cost(
                        &cfg, mm, &params, 0, chunk,
                    );
                    let serial: f64 = pc
                        .per_position
                        .iter()
                        .map(|c| c.latency.critical_ns())
                        .sum();
                    (pc.chunk_ns, serial)
                })
                .unwrap_or((0.0, 0.0));
            let meas = b
                .bench(&format!("prefill len={plen} chunk={chunk}"), || {
                    let slot = eng.try_admit().expect("slot free");
                    let mut fed = 0usize;
                    while fed < plen {
                        let c = chunk.min(plen - fed);
                        eng.step_chunks(&[(slot, &prompt[fed..fed + c])]);
                        fed += c;
                    }
                    eng.release(slot);
                })
                .clone();
            let tps = plen as f64 / (meas.mean_ns * 1e-9);
            if chunk == 1 {
                chunk1_tps = tps;
            }
            let speedup = tps / chunk1_tps.max(1e-12);
            println!(
                "  -> len={plen} chunk={chunk}: {:.0} prefill tokens/s wall, {:.2}x vs chunk=1",
                tps, speedup,
            );
            prefill_records.push((
                format!("len_{plen}_chunk_{chunk}"),
                obj(vec![
                    ("prompt_len", num(plen as f64)),
                    ("chunk", num(chunk as f64)),
                    ("tokens_per_sec", num(tps)),
                    ("ns_per_token", num(meas.mean_ns / plen as f64)),
                    ("speedup_vs_chunk1", num(speedup)),
                    ("modeled_chunk_ns", num(modeled_chunk_ns)),
                    (
                        "modeled_speedup",
                        num(modeled_serial_ns / modeled_chunk_ns.max(1e-12)),
                    ),
                ]),
            ));
        }
    }
    write_json_artifact(
        "prefill-json",
        "BENCH_PREFILL_JSON",
        "BENCH_prefill.json",
        &obj(vec![
            ("bench", s("prefill_throughput")),
            ("model", s(cfg.name)),
            ("strategy", s("dense")),
            ("analog_passes_per_position", num(passes_per_position as f64)),
            ("sweep", sweep_obj(&prefill_records)),
        ]),
    );

    section("speculative decode sweep — K draft proposals, one batched verify (DenseMap)");
    // Each round verifies K+1 positions through ONE chunked replay
    // (sim::speculate): the modeled win is the pipelined verify pass vs
    // K+1 serial decode steps, discounted by the draft's own forwards
    // and by rejected lanes. The sweep crosses K with self-draft depth;
    // full depth (tiny: 2 layers) is a perfect draft and pins the best
    // case — accepted-tokens/round must exceed 1 there.
    let mut spec_records: Vec<(String, Json)> = Vec::new();
    let mut best_tokens_per_round = 0.0f64;
    {
        // plain greedy baseline: modeled serial latency of the generated
        // positions (the phase speculation accelerates)
        let mut plain = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
        );
        let plain_r = plain.generate(&PROMPT, TOKENS);
        let plain_gen_ns: f64 = plain_r.per_token[PROMPT.len()..]
            .iter()
            .map(|c| c.latency.critical_ns())
            .sum();
        for &layers in &[1usize, 2] {
            for &k in &[1usize, 2, 4, 8] {
                let mut spec = SpeculativeEngine::on_chip(
                    DecodeModel::synth(cfg.clone(), 2025),
                    self_draft_model(&cfg, 2025, layers),
                    params.clone(),
                    Strategy::DenseMap,
                    k,
                );
                let meas = b
                    .bench(&format!("speculative decode d{layers} K={k}"), || {
                        std::hint::black_box(spec.generate(&PROMPT, TOKENS))
                    })
                    .clone();
                let tps = (PROMPT.len() + TOKENS) as f64 / (meas.mean_ns * 1e-9);
                // one un-timed run for acceptance stats + cross-check
                let r = spec.generate(&PROMPT, TOKENS);
                assert_eq!(
                    r.tokens, plain_r.tokens,
                    "speculative decode diverged from plain greedy (d{layers} K={k})"
                );
                let tpr = r.tokens_per_round();
                best_tokens_per_round = best_tokens_per_round.max(tpr);
                let spec_ns = r.modeled_generation_ns();
                let speedup = plain_gen_ns / spec_ns.max(1e-12);
                println!(
                    "  -> d{layers} K={k}: acceptance {:.2}, {:.2} tokens/round, modeled speedup {:.2}x, {:.0} tokens/s wall",
                    r.acceptance_rate(),
                    tpr,
                    speedup,
                    tps,
                );
                spec_records.push((
                    format!("draft_{layers}_k_{k}"),
                    obj(vec![
                        ("draft_layers", num(layers as f64)),
                        ("k", num(k as f64)),
                        ("rounds", num(r.rounds.len() as f64)),
                        ("acceptance_rate", num(r.acceptance_rate())),
                        ("accepted_tokens_per_round", num(tpr)),
                        ("modeled_speedup_vs_plain", num(speedup)),
                        ("modeled_spec_ns", num(spec_ns)),
                        ("modeled_plain_ns", num(plain_gen_ns)),
                        ("tokens_per_sec", num(tps)),
                    ]),
                ));
            }
        }
        assert!(
            best_tokens_per_round > 1.0,
            "no self-draft configuration beat one token per round \
             (best {best_tokens_per_round})"
        );
    }
    write_json_artifact(
        "spec-json",
        "BENCH_SPEC_JSON",
        "BENCH_spec.json",
        &obj(vec![
            ("bench", s("speculative_decode")),
            ("model", s(cfg.name)),
            ("strategy", s("dense")),
            ("prompt_len", num(PROMPT.len() as f64)),
            ("generated_tokens", num(TOKENS as f64)),
            ("sweep", sweep_obj(&spec_records)),
        ]),
    );

    section("layer-sharded pipeline sweep — shards x in-flight streams (DenseMap)");
    // `shards` chips each hold a contiguous layer range and B concurrent
    // streams keep the pipeline full (sim::shard). The functional replay
    // is host-serial, so wall tokens/sec tracks total work; the win is
    // the MODELED makespan — speedup_vs_1chip from the per-stage
    // timeline approaches S·M/(S+M−1) once in-flight lanes M ≥ stages S
    // (S=4, M=4 → 2.29x; M=8 → 2.91x), discounted by the inter-chip
    // activation hops. shards=1 pins the identity baseline (~1.0x).
    let mut deep = ModelConfig::tiny();
    deep.name = "tiny-8l";
    deep.dec_layers = 8; // depth ≥ 2 layers/stage even at shards=4
    let deep_passes = (PROMPT.len() + TOKENS) as f64;
    let mut pipe_records: Vec<(String, Json)> = Vec::new();
    for &shards in &[1usize, 2, 4] {
        for &batch in &[1usize, 4, 8] {
            let mut eng = BatchDecodeEngine::sharded(
                DecodeModel::synth(deep.clone(), 2025),
                params.clone(),
                Strategy::DenseMap,
                batch,
                shards,
            );
            let prompts: Vec<Vec<i32>> = (0..batch)
                .map(|st| {
                    PROMPT
                        .iter()
                        .map(|&t| (t + st as i32) % deep.vocab as i32)
                        .collect()
                })
                .collect();
            let meas = b
                .bench(&format!("sharded decode S={shards} B={batch}"), || {
                    std::hint::black_box(eng.generate_batch_chunked(&prompts, TOKENS, 4))
                })
                .clone();
            let tps = batch as f64 * deep_passes / (meas.mean_ns * 1e-9);
            // one un-timed run cross-checked bit-for-bit against the
            // single-chip engine — sharding must not change a token
            let piped = eng.generate_batch_chunked(&prompts, TOKENS, 4);
            let mut mono = BatchDecodeEngine::on_chip(
                DecodeModel::synth(deep.clone(), 2025),
                params.clone(),
                Strategy::DenseMap,
                batch,
            );
            let want = mono.generate_batch_chunked(&prompts, TOKENS, 4);
            for (st, (a, w)) in piped.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.tokens, w.tokens,
                    "S={shards} B={batch} stream {st}: sharded decode diverged"
                );
            }
            let ps = eng.pipeline_stats();
            let speedup = ps.speedup_vs_1chip();
            let bubble = ps.bubble_fraction();
            let occ = ps.stage_occupancy();
            println!(
                "  -> S={shards} B={batch}: {:.0} tokens/s wall | modeled {:.2}x vs 1 chip, bubble {:.2}, occupancy [{}]",
                tps,
                speedup,
                bubble,
                occ.iter()
                    .map(|o| format!("{o:.2}"))
                    .collect::<Vec<_>>()
                    .join(", "),
            );
            if shards == 4 && batch >= 4 {
                // steady-state acceptance floor: with the pipeline full
                // (M ≥ S) the modeled overlap must beat 1.5x
                assert!(
                    speedup > 1.5,
                    "S={shards} B={batch}: modeled speedup {speedup:.2} \
                     did not clear the 1.5x pipeline floor"
                );
            }
            pipe_records.push((
                format!("shards_{shards}_batch_{batch}"),
                obj(vec![
                    ("shards", num(shards as f64)),
                    ("batch", num(batch as f64)),
                    ("stages", num(eng.stage_count() as f64)),
                    ("tokens_per_sec", num(tps)),
                    ("ns_per_token", num(meas.mean_ns / (batch as f64 * deep_passes))),
                    ("speedup_vs_1chip", num(speedup)),
                    ("bubble_fraction", num(bubble)),
                    (
                        "min_stage_occupancy",
                        num(occ.iter().cloned().fold(f64::INFINITY, f64::min)),
                    ),
                    ("pipeline_steps", num(ps.steps as f64)),
                    ("transfer_ns", num(ps.transfer_ns)),
                ]),
            ));
        }
    }
    write_json_artifact(
        "pipeline-json",
        "BENCH_PIPELINE_JSON",
        "BENCH_pipeline.json",
        &obj(vec![
            ("bench", s("pipeline_decode")),
            ("model", s(deep.name)),
            ("strategy", s("dense")),
            ("prompt_len", num(PROMPT.len() as f64)),
            ("generated_tokens", num(TOKENS as f64)),
            ("prefill_chunk", num(4.0)),
            ("sweep", sweep_obj(&pipe_records)),
        ]),
    );

    section("chip programming cost (map + compile plan + write)");
    for strategy in Strategy::all() {
        b.bench(&format!("program chip / {}", strategy.name()), || {
            std::hint::black_box(DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), 2025),
                params.clone(),
                strategy,
            ))
        });
    }

    // machine-readable perf artifact
    println!();
    write_json_artifact(
        "bench-json",
        "BENCH_JSON",
        "BENCH_decode.json",
        &obj(vec![
            ("bench", s("decode_throughput")),
            ("model", s(cfg.name)),
            ("prompt_len", num(PROMPT.len() as f64)),
            ("generated_tokens", num(TOKENS as f64)),
            ("tokens_per_iter", num(passes)),
            ("strategies", sweep_obj(&records)),
            ("batched", sweep_obj(&batched_records)),
        ]),
    );
}
