//! Bench: autoregressive decode throughput of the functional CIM chip
//! across the three mapping strategies, plus the modeled per-token
//! latency/energy the scheduler attributes to each (the paper's Fig. 7
//! quantities measured in their native regime — token-by-token decode
//! with a growing KV cache, instead of per-op matvecs).
//!
//! Reports host-wall-clock **tokens/sec** per strategy (the number the
//! compiled-plan replay optimizes), plus a batched sweep (B ∈ {1,2,4,8}
//! concurrent streams through one DenseMap chip via
//! `BatchDecodeEngine::generate_batch` — the serving amortization), and
//! writes a machine-readable `BENCH_decode.json` so the perf trajectory
//! is trackable per commit.
//!
//! ```text
//! cargo bench --bench decode_throughput                      # writes BENCH_decode.json
//! cargo bench --bench decode_throughput -- --bench-json out.json
//! BENCH_JSON=out.json cargo bench --bench decode_throughput  # env override
//! BENCH_QUICK=1 ...                                          # CI smoke mode
//! ```

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::util::bench::{section, Bencher};
use monarch_cim::util::json::{num, obj, s, Json};

const PROMPT: [i32; 4] = [11, 48, 85, 122];
const TOKENS: usize = 16;

/// Output path for the JSON artifact: `--bench-json <path>` (or
/// `--bench-json=<path>`) > `BENCH_JSON` env var > `BENCH_decode.json`.
fn bench_json_path() -> std::path::PathBuf {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--bench-json" {
            if let Some(p) = args.next() {
                return p.into();
            }
        } else if let Some(p) = a.strip_prefix("--bench-json=") {
            return p.into();
        }
    }
    if let Some(p) = std::env::var_os("BENCH_JSON") {
        return p.into();
    }
    "BENCH_decode.json".into()
}

fn main() {
    let cfg = ModelConfig::tiny();
    let params = CimParams::default();
    let mut b = Bencher::new();
    // each generate() runs prompt + generated forward passes
    let passes = (PROMPT.len() + TOKENS) as f64;
    let mut records: Vec<(String, Json)> = Vec::new();

    section("decode engine — functional-sim throughput (tiny model)");
    let mut reference = DecodeEngine::reference(DecodeModel::synth(cfg.clone(), 2025));
    let meas = b
        .bench("reference decode 16 tokens", || {
            std::hint::black_box(reference.generate(&PROMPT, TOKENS))
        })
        .clone();
    let ref_tps = passes / (meas.mean_ns * 1e-9);
    println!("  -> {ref_tps:.0} tokens/s (host wall-clock)");
    records.push((
        "Reference".to_string(),
        obj(vec![
            ("tokens_per_sec", num(ref_tps)),
            ("ns_per_token", num(meas.mean_ns / passes)),
        ]),
    ));

    for strategy in Strategy::all() {
        let mut eng = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            strategy,
        );
        let meas = b
            .bench(&format!("{} decode 16 tokens", strategy.name()), || {
                std::hint::black_box(eng.generate(&PROMPT, TOKENS))
            })
            .clone();
        let tps = passes / (meas.mean_ns * 1e-9);
        let arrays = eng.mapping().map(|mm| mm.arrays).unwrap_or(0);
        // one un-timed run for the modeled per-token cost breakdown
        let r = eng.generate(&PROMPT, TOKENS);
        let total = r.total();
        let n_tok = r.per_token.len().max(1) as f64;
        println!(
            "  -> {:.0} tokens/s wall ({:.2} µs/token) | modeled chip: {:.3} µs/token, {:.1} nJ/token ({} arrays)",
            tps,
            meas.mean_ns / passes / 1e3,
            total.latency.critical_ns() / n_tok / 1e3,
            total.energy.total_nj() / n_tok,
            arrays,
        );
        println!(
            "  -> last-token MHA share: {:.0} ns of {:.0} ns critical path (KV cache {} entries)",
            r.per_token.last().map(|c| c.latency.mha_ns).unwrap_or(0.0),
            r.per_token
                .last()
                .map(|c| c.latency.critical_ns())
                .unwrap_or(0.0),
            PROMPT.len() + TOKENS,
        );
        records.push((
            strategy.name().to_string(),
            obj(vec![
                ("tokens_per_sec", num(tps)),
                ("ns_per_token", num(meas.mean_ns / passes)),
                ("speedup_vs_reference", num(tps / ref_tps)),
                ("modeled_ns_per_token", num(total.latency.critical_ns() / n_tok)),
                ("modeled_nj_per_token", num(total.energy.total_nj() / n_tok)),
                ("arrays", num(arrays as f64)),
            ]),
        ));
    }

    section("batched decode sweep — B concurrent streams, one DenseMap chip");
    let mut batched_records: Vec<(String, Json)> = Vec::new();
    let mut b1_tps = 0.0f64;
    for batch in [1usize, 2, 4, 8] {
        let mut eng = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), 2025),
            params.clone(),
            Strategy::DenseMap,
            batch,
        );
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|s| PROMPT.iter().map(|&t| (t + s as i32) % cfg.vocab as i32).collect())
            .collect();
        let meas = b
            .bench(&format!("dense batched decode B={batch}"), || {
                std::hint::black_box(eng.generate_batch(&prompts, TOKENS))
            })
            .clone();
        // every stream advances prompt+TOKENS positions per iteration
        let tps = batch as f64 * passes / (meas.mean_ns * 1e-9);
        if batch == 1 {
            b1_tps = tps;
        }
        println!(
            "  -> B={batch}: {:.0} tokens/s wall ({:.2} µs/token-step), {:.2}x vs B=1",
            tps,
            meas.mean_ns / passes / 1e3,
            tps / b1_tps.max(1e-12),
        );
        batched_records.push((
            format!("batch_{batch}"),
            obj(vec![
                ("batch", num(batch as f64)),
                ("tokens_per_sec", num(tps)),
                ("ns_per_token", num(meas.mean_ns / (batch as f64 * passes))),
                ("speedup_vs_b1", num(tps / b1_tps.max(1e-12))),
            ]),
        ));
    }

    section("chip programming cost (map + compile plan + write)");
    for strategy in Strategy::all() {
        b.bench(&format!("program chip / {}", strategy.name()), || {
            std::hint::black_box(DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), 2025),
                params.clone(),
                strategy,
            ))
        });
    }

    // machine-readable perf artifact
    let path = bench_json_path();
    let doc = obj(vec![
        ("bench", s("decode_throughput")),
        ("model", s(cfg.name)),
        ("prompt_len", num(PROMPT.len() as f64)),
        ("generated_tokens", num(TOKENS as f64)),
        ("tokens_per_iter", num(passes)),
        (
            "strategies",
            obj(records.iter().map(|(k, v)| (k.as_str(), v.clone())).collect()),
        ),
        (
            "batched",
            obj(batched_records
                .iter()
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect()),
        ),
    ]);
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
