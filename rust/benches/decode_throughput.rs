//! Bench: autoregressive decode throughput of the functional CIM chip
//! across the three mapping strategies, plus the modeled per-token
//! latency/energy the scheduler attributes to each (the paper's Fig. 7
//! quantities measured in their native regime — token-by-token decode
//! with a growing KV cache — instead of per-op matvecs).
//!
//! `cargo bench --bench decode_throughput`

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::sim::decode::{DecodeEngine, DecodeModel};
use monarch_cim::util::bench::{section, Bencher};

const PROMPT: [i32; 4] = [11, 48, 85, 122];
const TOKENS: usize = 16;

fn main() {
    let cfg = ModelConfig::tiny();
    let params = CimParams::default();
    let mut b = Bencher::new();

    section("decode engine — functional-sim throughput (tiny model)");
    let mut reference = DecodeEngine::reference(DecodeModel::synth(&cfg, 2025));
    // each generate() runs prompt + generated forward passes
    let passes = (PROMPT.len() + TOKENS) as f64;
    let m = b
        .bench("reference decode 16 tokens", || {
            std::hint::black_box(reference.generate(&PROMPT, TOKENS))
        })
        .clone();
    println!(
        "  -> {:.0} simulated forward passes/s (host wall-clock)",
        passes / (m.mean_ns * 1e-9)
    );

    for strategy in Strategy::all() {
        let mut eng =
            DecodeEngine::on_chip(DecodeModel::synth(&cfg, 2025), &params, strategy);
        let m = b
            .bench(&format!("{} decode 16 tokens", strategy.name()), || {
                std::hint::black_box(eng.generate(&PROMPT, TOKENS))
            })
            .clone();
        let r = eng.generate(&PROMPT, TOKENS);
        let total = eng.trace.total();
        println!(
            "  -> {:.0} simulated forward passes/s wall | modeled chip: {:.3} µs/token, {:.1} nJ/token ({} arrays)",
            passes / (m.mean_ns * 1e-9),
            eng.trace.mean_token_ns() / 1e3,
            eng.trace.mean_token_nj(),
            eng.mapping().map(|mm| mm.arrays).unwrap_or(0),
        );
        println!(
            "  -> last-token MHA share: {:.0} ns of {:.0} ns critical path (KV cache {} entries)",
            r.per_token.last().map(|c| c.latency.mha_ns).unwrap_or(0.0),
            r.per_token
                .last()
                .map(|c| c.latency.critical_ns())
                .unwrap_or(0.0),
            PROMPT.len() + TOKENS,
        );
        let _ = total;
    }

    section("chip programming cost (map + write commands)");
    for strategy in Strategy::all() {
        b.bench(&format!("program chip / {}", strategy.name()), || {
            std::hint::black_box(DecodeEngine::on_chip(
                DecodeModel::synth(&cfg, 2025),
                &params,
                strategy,
            ))
        });
    }
}
