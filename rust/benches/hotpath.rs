//! Hot-path microbenchmarks for the §Perf pass: the matmul kernels, the
//! D2S projection, Monarch apply, the DenseMap packer, the cost model,
//! the batched pass-table replay (bit-block vs index-list encodings)
//! and the PJRT execution path (throughput of the end-to-end serving
//! stack).
//!
//! `cargo bench --bench hotpath`

use std::sync::Arc;

use monarch_cim::cim::CimParams;
use monarch_cim::coordinator::tracing::{Event, EventKind, Tracer, WorkerTrace};
use monarch_cim::mapping::{map_model, Strategy};
use monarch_cim::model::ModelConfig;
use monarch_cim::monarch::{monarch_project, MonarchMatrix};
use monarch_cim::runtime::{literal_f32, literals_from_monarch, Runtime};
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeModel};
use monarch_cim::sim::exec::ReplayMode;
use monarch_cim::tensor::{matmul, Matrix};
use monarch_cim::util::bench::{section, Bencher};
use monarch_cim::util::rng::Pcg32;

/// The serving worker's step shape with the §6h trace sites inlined:
/// admit, read pre-step trace lengths, one multi-lane `step_chunks`, one
/// chunk event per slot (modeled-ns delta off `slot_trace`) plus the
/// per-step worker event, release. With `wt == None` every site is the
/// same skipped `Option` check the server pays, so the disabled-path
/// delta vs [`batched_replay_round`] is the true cost of having tracing
/// compiled in (< 2% acceptance, DESIGN.md §6h).
fn traced_replay_round(
    eng: &mut BatchDecodeEngine,
    chunks: &[Vec<i32>],
    wt: &mut Option<WorkerTrace>,
    pre_lens: &mut Vec<usize>,
) -> Vec<f32> {
    let slots: Vec<usize> = chunks
        .iter()
        .map(|_| eng.try_admit().expect("fresh engine has a free slot"))
        .collect();
    let t0 = wt.as_ref().map(|w| w.now_us()).unwrap_or(0.0);
    pre_lens.clear();
    if wt.is_some() {
        pre_lens.extend(slots.iter().map(|&s| eng.slot_trace(s).len()));
    }
    let groups: Vec<(usize, &[i32])> = slots
        .iter()
        .zip(chunks)
        .map(|(&s, c)| (s, &c[..]))
        .collect();
    eng.step_chunks(&groups);
    let t1 = wt.as_ref().map(|w| w.now_us()).unwrap_or(0.0);
    let mut step_sim_ns = 0.0f64;
    for (i, (&slot, c)) in slots.iter().zip(chunks).enumerate() {
        let chunk_sim_ns = if wt.is_some() {
            eng.slot_trace(slot)[pre_lens[i]..]
                .iter()
                .map(|p| p.latency.critical_ns())
                .sum::<f64>()
        } else {
            0.0
        };
        step_sim_ns += chunk_sim_ns;
        if let Some(w) = wt.as_mut() {
            w.record(
                Event::span(EventKind::PrefillChunk, i as u64 + 1, 0, t0, t1)
                    .ab(c.len() as u32, 0)
                    .sim(chunk_sim_ns),
            );
        }
    }
    if let Some(w) = wt.as_mut() {
        w.record(
            Event::span(EventKind::WorkerStep, 0, 0, t0, t1)
                .ab(32, slots.len() as u32)
                .sim(step_sim_ns),
        );
    }
    let logits: Vec<f32> = slots
        .iter()
        .flat_map(|&s| eng.logits(s).iter().copied())
        .collect();
    for s in slots {
        eng.release(s);
    }
    logits
}

/// One admit→multi-lane `step_chunks`→release round through the batched
/// engine; returns the concatenated slot logits so the two pass-table
/// encodings can be cross-checked bitwise.
fn batched_replay_round(eng: &mut BatchDecodeEngine, chunks: &[Vec<i32>]) -> Vec<f32> {
    let slots: Vec<usize> = chunks
        .iter()
        .map(|_| eng.try_admit().expect("fresh engine has a free slot"))
        .collect();
    let groups: Vec<(usize, &[i32])> = slots
        .iter()
        .zip(chunks)
        .map(|(&s, c)| (s, &c[..]))
        .collect();
    eng.step_chunks(&groups);
    let logits: Vec<f32> = slots
        .iter()
        .flat_map(|&s| eng.logits(s).iter().copied())
        .collect();
    for s in slots {
        eng.release(s);
    }
    logits
}

fn main() {
    let mut rng = Pcg32::new(40);
    let mut b = Bencher::new();

    section("L3 tensor substrate");
    for n in [64usize, 256, 512] {
        let a = Matrix::randn(n, n, &mut rng);
        let c = Matrix::randn(n, n, &mut rng);
        let m = b.bench(&format!("matmul {n}x{n}"), || {
            std::hint::black_box(matmul::matmul(&a, &c))
        });
        let gflops = 2.0 * (n as f64).powi(3) / m.mean_ns;
        println!("  -> {gflops:.2} GFLOP/s");
    }

    section("D2S projection (rank-1 SVD per slice)");
    for (d, bsz) in [(64usize, 8usize), (256, 16), (1024, 32)] {
        let base = MonarchMatrix::randn(bsz, &mut rng)
            .to_dense()
            .scale(1.0 / bsz as f32);
        let w = base.add(&Matrix::randn(d, d, &mut rng).scale(0.01));
        b.bench(&format!("monarch_project {d}x{d}"), || {
            std::hint::black_box(monarch_project(&w))
        });
    }

    section("Monarch apply (factored MVM)");
    for bsz in [8usize, 32] {
        let m = MonarchMatrix::randn(bsz, &mut rng);
        let x = rng.normal_vec(m.n());
        let meas = b.bench(&format!("monarch matvec n={}", m.n()), || {
            std::hint::black_box(m.matvec(&x))
        });
        let flops = m.mvm_flops() as f64;
        println!("  -> {:.2} GFLOP/s", flops / meas.mean_ns);
    }

    section("mapping + scheduling");
    let params = CimParams::default();
    let bert = ModelConfig::bert_large();
    b.bench("DenseMap pack bert-large", || {
        std::hint::black_box(map_model(&bert, &params, Strategy::DenseMap))
    });
    b.bench("cost_report bert-large DenseMap", || {
        std::hint::black_box(cost_report(&bert, &params, Strategy::DenseMap))
    });

    section("batched pass-table replay — bit-block vs index-list (DESIGN.md §6e)");
    // The serving hot loop: one multi-lane `step_chunks` drives 8
    // streams x 4 positions = 32 lanes through the compiled pass
    // tables. Both encodings replay bit-identically, so the delta is
    // pure loop speed over the table representation.
    let tiny = ModelConfig::tiny();
    let chunks: Vec<Vec<i32>> = (0..8usize)
        .map(|s| {
            (0..4)
                .map(|p| ((s * 37 + p * 11 + 5) % tiny.vocab) as i32)
                .collect()
        })
        .collect();
    let positions: f64 = chunks.iter().map(|c| c.len() as f64).sum();
    let mut eng = BatchDecodeEngine::on_chip(
        DecodeModel::synth(tiny.clone(), 2025),
        params.clone(),
        Strategy::DenseMap,
        chunks.len(),
    );
    let bb = b
        .bench("step_chunks 8x4 lanes (bit-block)", || {
            std::hint::black_box(batched_replay_round(&mut eng, &chunks))
        })
        .clone();
    let bb_pps = positions / (bb.mean_ns * 1e-9);
    eng.set_replay_mode(ReplayMode::IndexList);
    let il = b
        .bench("step_chunks 8x4 lanes (index list)", || {
            std::hint::black_box(batched_replay_round(&mut eng, &chunks))
        })
        .clone();
    let il_pps = positions / (il.mean_ns * 1e-9);
    // one un-timed round per encoding: outputs must be bit-identical
    let got_il = batched_replay_round(&mut eng, &chunks);
    eng.set_replay_mode(ReplayMode::BitBlock);
    let got_bb = batched_replay_round(&mut eng, &chunks);
    assert_eq!(
        got_bb, got_il,
        "bit-block and index-list batched replay must agree bitwise"
    );
    println!(
        "  -> bit-block {bb_pps:.0} vs index {il_pps:.0} positions/s ({:.2}x), outputs bit-identical",
        bb_pps / il_pps.max(1e-12),
    );

    section("request tracing overhead (DESIGN.md §6h)");
    // Same 8x4-lane serving step with the server's trace sites inlined.
    // Disabled tracing is `Option` checks only and must stay within
    // noise (< 2% acceptance) of the bare loop; enabled tracing pays one
    // ring push per slot per step, never per lane.
    let bare = b
        .bench("step 8x4 bare loop", || {
            std::hint::black_box(batched_replay_round(&mut eng, &chunks))
        })
        .clone();
    let mut pre_lens: Vec<usize> = Vec::new();
    let mut wt_off: Option<WorkerTrace> = None;
    let off = b
        .bench("step 8x4 tracing disabled", || {
            std::hint::black_box(traced_replay_round(
                &mut eng,
                &chunks,
                &mut wt_off,
                &mut pre_lens,
            ))
        })
        .clone();
    let tracer = Arc::new(Tracer::new(65536));
    let mut wt_on: Option<WorkerTrace> = Some(tracer.worker(0));
    let on = b
        .bench("step 8x4 tracing enabled", || {
            std::hint::black_box(traced_replay_round(
                &mut eng,
                &chunks,
                &mut wt_on,
                &mut pre_lens,
            ))
        })
        .clone();
    drop(wt_on);
    println!(
        "  -> bare {:.0} / disabled {:.0} / enabled {:.0} positions/s; disabled-path overhead {:+.2}%, enabled {:+.2}% ({} events ringed)",
        positions / (bare.mean_ns * 1e-9),
        positions / (off.mean_ns * 1e-9),
        positions / (on.mean_ns * 1e-9),
        (off.mean_ns / bare.mean_ns - 1.0) * 100.0,
        (on.mean_ns / bare.mean_ns - 1.0) * 100.0,
        tracer.events().len(),
    );

    section("PJRT runtime (requires `make artifacts`)");
    match Runtime::with_default_dir() {
        Err(e) => println!("  skipped: {e}"),
        Ok(mut rt) => {
            let m = MonarchMatrix::randn(32, &mut rng);
            let x = Matrix::randn(4, 1024, &mut rng);
            let (l, r) = literals_from_monarch(&m).unwrap();
            let xl = literal_f32(&x.data, &[4, 1024]).unwrap();
            rt.execute("monarch_mvm_n1024", &[l, r, xl]).unwrap();
            let meas = b.bench("pjrt monarch_mvm_n1024 (batch 4)", || {
                let (l, r) = literals_from_monarch(&m).unwrap();
                let xl = literal_f32(&x.data, &[4, 1024]).unwrap();
                std::hint::black_box(
                    rt.execute("monarch_mvm_n1024", &[l, r, xl]).unwrap(),
                )
            });
            println!(
                "  -> {:.0} rows/s through the AOT kernel",
                4.0 / (meas.mean_ns * 1e-9)
            );
            // token throughput of the tiny-LM artifact (the serving path)
            let toks = vec![1i32; 8 * 32];
            let tl = monarch_cim::runtime::literal_i32(&toks, &[8, 32]).unwrap();
            rt.execute("tiny_lm_b8", &[tl]).unwrap();
            let meas = b.bench("pjrt tiny_lm_b8 (8 x 32 tokens)", || {
                let tl = monarch_cim::runtime::literal_i32(&toks, &[8, 32]).unwrap();
                std::hint::black_box(rt.execute("tiny_lm_b8", &[tl]).unwrap())
            });
            println!(
                "  -> {:.0} tok/s end-to-end",
                (8.0 * 32.0) / (meas.mean_ns * 1e-9)
            );
        }
    }
}
