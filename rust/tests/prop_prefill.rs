//! Property tests for chunked prefill (`sim::prefill`, DESIGN.md §6c):
//! over random model geometries, mapping strategies, chunk sizes 1..=S
//! and chunk *partitions*, position-parallel prompt ingestion is
//! **bit-identical** to token-by-token feeding — per-position logits,
//! KV-cache contents, greedy token sequences and per-position cost
//! records — including mid-chunk admission into a busy
//! [`BatchDecodeEngine`] whose neighbours keep decoding.
//!
//! This is the ISSUE-4 acceptance property: chunking changes only *how
//! many positions share one batched replay* (lanes = positions), never
//! what any position computes, because each lane replays exactly the
//! single-stream f32 operations and causal attention is a cache-prefix
//! bound.

use monarch_cim::model::ModelConfig;
use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::util::prop::forall;

mod common;

#[test]
fn prop_chunked_prefill_bit_identical_to_token_by_token() {
    // Step-level: feed one prompt through random-size chunks and compare
    // every observable — per-position logits (lane order), the slot's
    // last logits, and the full KV cache — bitwise against forward().
    forall("chunked prefill == token-by-token forward", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let plen = g.usize(1, 12);
        let prompt: Vec<i32> = (0..plen)
            .map(|i| ((i * 13 + 5) % cfg.vocab) as i32)
            .collect();
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            1,
        );
        let mut single = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        let slot = be.try_admit().unwrap();
        let mut fed = 0usize;
        while fed < plen {
            let c = g.usize(1, (plen - fed).min(8)); // random chunk partition
            be.step_chunks(&[(slot, &prompt[fed..fed + c])]);
            // every position of the chunk must match forward() bitwise
            for i in 0..c {
                let want = single.forward(prompt[fed + i]).to_vec();
                assert_eq!(
                    be.lane_logits(i),
                    want.as_slice(),
                    "{strategy:?} chunk at {fed} size {c}: lane {i} logits drifted"
                );
            }
            // the slot's persisted logits are the chunk's last position
            assert_eq!(
                be.logits(slot),
                be.lane_logits(c - 1),
                "slot logits must be the chunk's last lane"
            );
            fed += c;
        }
        // KV caches identical, bit for bit, at every layer and position
        assert_eq!(be.kv_len(slot), single.kv_len());
        for l in 0..cfg.dec_layers {
            for pos in 0..plen {
                assert_eq!(
                    be.kv(slot).key(l, pos),
                    single.kv_cache().key(l, pos),
                    "{strategy:?} layer {l} pos {pos}: key drifted"
                );
                assert_eq!(
                    be.kv(slot).value(l, pos),
                    single.kv_cache().value(l, pos),
                    "{strategy:?} layer {l} pos {pos}: value drifted"
                );
            }
        }
    });
}

#[test]
fn prop_chunked_generate_equals_independent_engines() {
    // End-to-end: generate_batch_chunked over random chunk sizes,
    // capacities and ragged prompts (more requests than slots → mid-run
    // eviction + admission, so fresh prompts prefill while in-flight
    // neighbours decode in the SAME steps) must reproduce independent
    // single-stream engines token-for-token and cost-for-cost.
    forall("chunked generate_batch == single-stream engines", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let capacity = g.usize(1, 4);
        let n_requests = capacity + g.usize(0, 3);
        let n_tokens = g.usize(1, 4);
        let chunk = g.usize(1, cfg.seq); // 1..=S
        let prompts: Vec<Vec<i32>> = (0..n_requests)
            .map(|r| {
                let len = g.usize(1, 8); // ragged prompt lengths
                (0..len)
                    .map(|i| ((r * 31 + i * 7 + 3) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let mut batched = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        let results = batched.generate_batch_chunked(&prompts, n_tokens, chunk);
        assert_eq!(results.len(), n_requests);
        assert_eq!(batched.occupancy(), 0, "all slots evicted after the run");
        let mut single = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        for (ri, (p, r)) in prompts.iter().zip(&results).enumerate() {
            let want = single.generate(p, n_tokens);
            assert_eq!(
                r.tokens, want.tokens,
                "{strategy:?} capacity {capacity} chunk {chunk} request {ri}: \
                 chunked tokens diverged from an independent engine"
            );
            assert_eq!(
                r.per_token.len(),
                want.per_token.len(),
                "{strategy:?} request {ri}: per-position cost count"
            );
            // chunking must not change per-position accounting — the
            // physical per-position work is the same (trace.rs model)
            for (i, (a, w)) in r.per_token.iter().zip(&want.per_token).enumerate() {
                assert_eq!(
                    a.latency.critical_ns(),
                    w.latency.critical_ns(),
                    "{strategy:?} request {ri} position {i}: latency drift"
                );
                assert_eq!(
                    a.energy.total_nj(),
                    w.energy.total_nj(),
                    "{strategy:?} request {ri} position {i}: energy drift"
                );
            }
        }
    });
}

#[test]
fn prop_mid_chunk_admission_leaves_neighbours_untouched() {
    // A slot mid-decode steps together with a freshly admitted slot
    // prefilling a whole chunk; both must stay bit-identical to their
    // single-stream twins — the continuous-batching integration point.
    forall("mid-chunk admission is interference-free", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::monarch_strategy(g);
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            2,
        );
        let mk_engine = || {
            DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                params.clone(),
                strategy,
            )
        };
        // slot 0: established request with a few cached positions
        let warm: Vec<i32> = (0..g.usize(1, 4))
            .map(|i| ((i * 19 + 2) % cfg.vocab) as i32)
            .collect();
        let s0 = be.try_admit().unwrap();
        be.step_chunks(&[(s0, &warm[..])]);
        let mut e0 = mk_engine();
        for &t in &warm {
            e0.forward(t);
        }
        // slot 1 admitted mid-run; its whole prompt arrives as ONE chunk
        // in the same step that advances slot 0 by one decode token
        let s1 = be.try_admit().unwrap();
        let fresh: Vec<i32> = (0..g.usize(1, 6))
            .map(|i| ((i * 23 + 7) % cfg.vocab) as i32)
            .collect();
        let next0 = ((warm.len() * 3 + 1) % cfg.vocab) as i32;
        be.step_chunks(&[(s0, &[next0][..]), (s1, &fresh[..])]);
        let want0 = e0.forward(next0).to_vec();
        assert_eq!(
            be.logits(s0),
            want0.as_slice(),
            "{strategy:?}: decode lane disturbed by a neighbour's prefill"
        );
        let mut e1 = mk_engine();
        let mut want1 = Vec::new();
        for &t in &fresh {
            want1 = e1.forward(t).to_vec();
        }
        assert_eq!(
            be.logits(s1),
            want1.as_slice(),
            "{strategy:?}: prefill chunk disturbed by a decode lane"
        );
        // flattened lane order: slot 0's single token, then the chunk
        assert_eq!(be.lane_logits(0), want0.as_slice());
        assert_eq!(be.lane_logits(fresh.len()), want1.as_slice());
    });
}

#[test]
fn overlong_requests_are_rejected_at_admission() {
    // ISSUE-4 satellite regression: prompt + generation beyond the
    // context window must fail loudly (no silent last-position reuse) on
    // every ingestion path, while exactly-full windows stay valid.
    let cfg = ModelConfig::tiny();
    let seq = cfg.seq;
    let overlong: Vec<i32> = vec![1; seq + 1];
    let fits: Vec<i32> = vec![1; seq];

    let r = std::panic::catch_unwind(|| {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(ModelConfig::tiny(), 1));
        eng.score(&overlong)
    });
    assert!(r.is_err(), "score must reject seq+1 tokens");

    let r = std::panic::catch_unwind(|| {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(ModelConfig::tiny(), 1));
        eng.generate(&fits[..4], seq) // 4 + seq > seq
    });
    assert!(r.is_err(), "generate must reject prompt+gen > seq");

    let r = std::panic::catch_unwind(|| {
        let mut be =
            BatchDecodeEngine::reference(DecodeModel::synth(ModelConfig::tiny(), 1), 1);
        be.generate_batch_chunked(&[overlong.clone()], 0, 4)
    });
    assert!(r.is_err(), "chunked admission must reject overlong prompts");

    // the boundary case is servable end to end, chunked or not
    let mut be = BatchDecodeEngine::reference(DecodeModel::synth(ModelConfig::tiny(), 1), 1);
    let out = be.generate_batch_chunked(&[fits.clone()], 0, 5);
    assert_eq!(out[0].per_token.len(), seq);
    let mut eng = DecodeEngine::reference(DecodeModel::synth(ModelConfig::tiny(), 1));
    let (logits, _) = eng.score(&fits);
    assert_eq!(logits.len(), seq * cfg.vocab);
}
