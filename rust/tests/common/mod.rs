//! Shared generators for the property-test suites (ISSUE-5 satellite):
//! the random decoder-config / chip-parameter / strategy / geometry
//! pickers that were previously duplicated across `prop_prefill.rs`,
//! `prop_batch_decode.rs` and `prop_exec_plan.rs`, with one seeded
//! entry point ([`seed`]). Every suite draws the same distributions, so
//! a geometry that breaks one engine path is automatically in reach of
//! the others.
//!
//! Each test binary compiles this module independently (`mod common;`)
//! and uses its own subset, hence the file-wide `dead_code` allowance.
#![allow(dead_code)]

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::{MatmulOp, ModelConfig, OpKind, Stage};
use monarch_cim::monarch::{MonarchMatrix, RectMonarch};
use monarch_cim::util::prop::Gen;
use monarch_cim::util::rng::Pcg32;

/// The single seeded entry point: a weight-synthesis / data seed drawn
/// from the property generator, so every suite derives its models the
/// same way and failures replay from the `forall` seed report.
pub fn seed(g: &mut Gen) -> u64 {
    g.usize(0, 1 << 30) as u64
}

/// Random decoder-only config with a perfect-square d_model and heads
/// dividing it (the decode engine's contract).
pub fn random_decoder_cfg(g: &mut Gen) -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = g.choose(&[16usize, 64]);
    cfg.n_heads = g.choose(&[2usize, 4]);
    cfg.d_ff = cfg.d_model * g.usize(1, 4);
    cfg.dec_layers = g.usize(1, 2);
    cfg.vocab = g.choose(&[64usize, 128]);
    cfg.seq = 16;
    cfg
}

/// Random CIM parameters with the array dimension drawn from `dims`.
pub fn chip_params(g: &mut Gen, dims: &[usize]) -> CimParams {
    let mut params = CimParams::default();
    params.array_dim = g.choose(dims);
    params
}

/// Whether `cfg`'s Monarch block fits the array (engine suites skip the
/// case otherwise — the mapping engines reject b > m by contract).
pub fn fits_array(cfg: &ModelConfig, params: &CimParams) -> bool {
    let b = (cfg.d_model as f64).sqrt().round() as usize;
    b <= params.array_dim
}

/// One of the three mapping strategies.
pub fn any_strategy(g: &mut Gen) -> Strategy {
    g.choose(&[Strategy::Linear, Strategy::SparseMap, Strategy::DenseMap])
}

/// One of the two Monarch strategies (bit-identical to the factored
/// reference — the suites that compare bitwise across engines use these).
pub fn monarch_strategy(g: &mut Gen) -> Strategy {
    g.choose(&[Strategy::SparseMap, Strategy::DenseMap])
}

/// Random transformer-shaped Para op list over d x d tiles (the plan /
/// scheduler suites' geometry source).
pub fn random_model_ops(g: &mut Gen, d: usize) -> (ModelConfig, Vec<MatmulOp>) {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = d;
    let layers = g.usize(1, 2);
    let ff_mult = g.usize(1, 4);
    let mut ops = Vec::new();
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo"] {
            ops.push(MatmulOp {
                name: format!("dec{l}.{w}"),
                stage: Stage::Decoder,
                layer: l,
                kind: OpKind::Para,
                rows: d,
                cols: d,
                batch: 1,
            });
        }
        ops.push(MatmulOp {
            name: format!("dec{l}.ffn1"),
            stage: Stage::Decoder,
            layer: l,
            kind: OpKind::Para,
            rows: ff_mult * d,
            cols: d,
            batch: 1,
        });
        ops.push(MatmulOp {
            name: format!("dec{l}.ffn2"),
            stage: Stage::Decoder,
            layer: l,
            kind: OpKind::Para,
            rows: d,
            cols: ff_mult * d,
            batch: 1,
        });
    }
    (cfg, ops)
}

/// Random tile grid for a rows x cols weight (d = tile dim).
pub fn rect_randn(rows: usize, cols: usize, d: usize, rng: &mut Pcg32) -> RectMonarch {
    let b = (d as f64).sqrt().round() as usize;
    let tiles = rows.div_ceil(d) * cols.div_ceil(d);
    RectMonarch {
        rows,
        cols,
        n: d,
        tiles: (0..tiles).map(|_| MonarchMatrix::randn(b, rng)).collect(),
    }
}
