//! Property tests: scheduler/timing invariants — cost positivity and
//! monotonicity, ADC policy bounds, functional-vs-schedule agreement on
//! random geometries. Geometry and seed generators come from
//! `tests/common/mod.rs`, shared with the engine suites.

use monarch_cim::cim::{adc, CimParams};
use monarch_cim::mapping::rotation::net_rotation;
use monarch_cim::mapping::{map_ops, Factor, Strategy};
use monarch_cim::model::ModelConfig;
use monarch_cim::monarch::{MonarchMatrix, StridePerm};
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::scheduler::{
    adc_bits_for, placement_block_coords, token_commands, usable_adcs, CimCommand,
};
use monarch_cim::sim::exec::{single_op, FunctionalChip};
use monarch_cim::util::prop::forall;
use monarch_cim::util::rng::Pcg32;

mod common;

#[test]
fn prop_costs_positive_and_finite() {
    forall("costs positive", 20, |g| {
        let model = g.choose(&[0usize, 1, 2]);
        let cfg = ModelConfig::paper_models()[model].clone();
        let adcs = g.choose(&[1usize, 2, 4, 8, 16, 32]);
        let p = CimParams::default().with_adcs_per_array(adcs);
        for s in Strategy::all() {
            let r = cost_report(&cfg, &p, s);
            assert!(r.latency_ms().is_finite() && r.latency_ms() > 0.0);
            assert!(r.energy_mj().is_finite() && r.energy_mj() > 0.0);
        }
    });
}

#[test]
fn prop_more_adcs_never_hurt() {
    forall("adcs monotone", 15, |g| {
        let cfg = ModelConfig::paper_models()[g.choose(&[0usize, 1, 2])].clone();
        let a1 = g.usize(1, 16);
        let a2 = a1 * 2;
        for s in Strategy::all() {
            let r1 = cost_report(&cfg, &CimParams::default().with_adcs_per_array(a1), s);
            let r2 = cost_report(&cfg, &CimParams::default().with_adcs_per_array(a2), s);
            assert!(
                r2.latency_ms() <= r1.latency_ms() + 1e-12,
                "{s:?}: {a2} ADCs slower than {a1}"
            );
        }
    });
}

#[test]
fn prop_adc_policy_bounds() {
    forall("adc bits within [1, ref]", 30, |g| {
        let p = CimParams::default();
        let b = g.usize(1, 64);
        for s in Strategy::all() {
            let bits = adc_bits_for(&p, s, b);
            assert!((1..=p.adc_ref_bits).contains(&bits));
            let u = usable_adcs(&p, s, b);
            assert!(u >= 1 && u <= p.adcs_per_array.max(1));
        }
        // resolution ordering: Linear >= SparseMap >= DenseMap at the
        // paper geometry family (b <= m)
        if (2..=p.array_dim).contains(&b) {
            let lin = adc_bits_for(&p, Strategy::Linear, b);
            let sp = adc_bits_for(&p, Strategy::SparseMap, b);
            let de = adc_bits_for(&p, Strategy::DenseMap, b);
            assert!(lin >= sp, "linear {lin} < sparse {sp} at b={b}");
            // dense uses m/b rows; for b <= sqrt(m) this can exceed b
            if b * b >= p.array_dim {
                assert!(sp >= de, "sparse {sp} < dense {de} at b={b}");
            }
        }
    });
}

#[test]
fn prop_sar_scaling_linear_in_bits() {
    forall("sar linear scaling", 20, |g| {
        let p = CimParams::default();
        let b1 = g.usize(1, 8) as u32;
        let b2 = g.usize(1, 8) as u32;
        let t1 = adc::t_conversion_ns(&p, b1);
        let t2 = adc::t_conversion_ns(&p, b2);
        assert!(
            (t1 / t2 - b1 as f64 / b2 as f64).abs() < 1e-9,
            "latency not linear in bits"
        );
        let e1 = adc::e_conversion_nj(&p, b1);
        let e2 = adc::e_conversion_nj(&p, b2);
        assert!((e1 / e2 - b1 as f64 / b2 as f64).abs() < 1e-9);
    });
}

#[test]
fn prop_functional_chip_correct_across_geometries() {
    // Random (d, m) geometry: the scheduled execution always reproduces
    // the Monarch operator.
    forall("functional correct", 12, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let strategy = if g.bool() {
            Strategy::SparseMap
        } else {
            Strategy::DenseMap
        };
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(common::seed(g));
        let mon = MonarchMatrix::randn(b, &mut rng);
        let mut chip =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon), &params, strategy);
        let x = rng.normal_vec(d);
        let got = chip.run_op(0, &x);
        let want = mon.matvec(&x);
        for (gv, w) in got.iter().zip(&want) {
            assert!(
                (gv - w).abs() < 2e-3 * (1.0 + w.abs()),
                "{strategy:?} d={d} m={m}"
            );
        }
    });
}

#[test]
fn prop_token_commands_activate_only_mapped_rows() {
    // Every DriveRows/Convert in the per-token command stream of a whole
    // mapped model must stay within the rows/columns its array actually
    // has placements on — the §III-C guarantee that packed layouts are
    // never driven outside their blocks.
    forall("token commands within placements", 12, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = common::random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        for strategy in Strategy::all() {
            let mm = map_ops(&cfg, &ops, &params, strategy);
            // allowed rows/cols per array, from the placements themselves
            let mut rows_ok = vec![std::collections::HashSet::new(); mm.arrays];
            let mut cols_ok = vec![std::collections::HashSet::new(); mm.arrays];
            for p in &mm.placements {
                let edge = p.block_dim.min(mm.m);
                for (r0, c0) in placement_block_coords(p, mm.m) {
                    rows_ok[p.array].extend(r0..r0 + edge);
                    cols_ok[p.array].extend(c0..c0 + edge);
                }
            }
            let cmds = token_commands(&mm, &params);
            assert!(!cmds.is_empty(), "{strategy:?}: empty command stream");
            let expected_bits = adc_bits_for(&params, strategy, mm.b);
            for cmd in &cmds {
                match cmd {
                    CimCommand::DriveRows { array, rows } => {
                        assert!(*array < mm.arrays);
                        assert!(!rows.is_empty());
                        for r in rows {
                            assert!(
                                rows_ok[*array].contains(r),
                                "{strategy:?}: array {array} row {r} driven without a placement"
                            );
                        }
                        if strategy == Strategy::DenseMap {
                            // §III-C row-group walk: one block per pass
                            assert_eq!(rows.len(), mm.b, "{strategy:?}: walk granularity");
                        }
                    }
                    CimCommand::Convert { array, cols, bits } => {
                        assert_eq!(*bits, expected_bits);
                        for c in cols {
                            assert!(
                                cols_ok[*array].contains(c),
                                "{strategy:?}: array {array} col {c} converted without a placement"
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
    });
}

#[test]
fn prop_densemap_lane_pairs_cancel_rotation() {
    // Under random model configs, every DenseMap (op, tile, chunk) pair
    // of L/R lanes must satisfy i_R = -i_L (mod lanes) so that the two
    // stage rotations cancel (§III-B2a).
    forall("i_R = -i_L mod lanes", 15, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = common::random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mm = map_ops(&cfg, &ops, &params, Strategy::DenseMap);
        let lanes = m / b;
        let mut left = std::collections::HashMap::new();
        let mut right = std::collections::HashMap::new();
        for p in &mm.placements {
            let key = (p.op, p.tile, p.lane_of_factor);
            match p.factor {
                Factor::Left => {
                    assert!(left.insert(key, p.diag).is_none(), "dup L at {key:?}");
                }
                Factor::Right => {
                    assert!(right.insert(key, p.diag).is_none(), "dup R at {key:?}");
                }
                Factor::Dense => panic!("dense placement in DenseMap"),
            }
        }
        assert_eq!(left.len(), right.len(), "unpaired lanes");
        for (key, &il) in &left {
            let ir = *right.get(key).unwrap_or_else(|| panic!("no R for {key:?}"));
            assert_eq!(
                ir,
                (lanes - il % lanes) % lanes,
                "{key:?}: i_R != -i_L mod lanes (i_L={il}, i_R={ir})"
            );
            assert_eq!(net_rotation(il, ir, lanes), 0);
        }
    });
}

#[test]
fn prop_dense_stage_isolation() {
    // Running only the R stage touches only Right placements: outputs
    // must be independent of the L factor's values.
    forall("stage isolation", 8, |g| {
        let d = 64;
        let m = 32;
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let seed = common::seed(g);
        let mut rng = Pcg32::new(seed);
        let b = cfg.monarch_b();
        let mon1 = MonarchMatrix::randn(b, &mut rng);
        let mut mon2 = mon1.clone();
        // different L, same R
        let mut rng2 = Pcg32::new(seed ^ 0xdead);
        mon2.l = monarch_cim::monarch::BlockDiag::randn(b, b, &mut rng2);
        let chip1 =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon1), &params, Strategy::DenseMap);
        let chip2 =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon2), &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        let xp = StridePerm::new(b).apply(&x);
        let r1 = chip1.run_stage(0, Factor::Right, &xp);
        let r2 = chip2.run_stage(0, Factor::Right, &xp);
        for (a, c) in r1.iter().zip(&r2) {
            assert!((a - c).abs() < 1e-6, "R stage leaked L values");
        }
    });
}
