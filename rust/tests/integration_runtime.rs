//! Integration: Rust coordinator <-> AOT JAX/Pallas artifacts via PJRT.
//!
//! These tests are the cross-language correctness contract: the Rust
//! Monarch implementation and the Pallas kernels must agree (up to float
//! tolerance) on the layouts defined in `python/compile/kernels/ref.py`.
//!
//! They require `make artifacts` AND a PJRT-enabled build (the offline
//! image stubs the `xla` crate — see `src/xla.rs`). When the runtime is
//! unavailable each test SKIPS (prints why and returns) instead of
//! failing: the equivalent numeric contracts are covered without PJRT by
//! `tests/integration_decode.rs` on the CIM-sim backend.

use monarch_cim::monarch::{monarch_project, BlockDiag, MonarchMatrix};
use monarch_cim::runtime::{
    literal_f32, literal_from_blockdiag, literal_i32, literals_from_monarch, Runtime,
};
use monarch_cim::tensor::Matrix;
use monarch_cim::util::json::Json;
use monarch_cim::util::rng::Pcg32;

/// PJRT runtime, or `None` (with a skip notice) when the artifacts or
/// the native XLA bundle are missing.
fn runtime() -> Option<Runtime> {
    match Runtime::with_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn block_diag_kernel_matches_rust() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(11);
    let bd = BlockDiag::randn(8, 8, &mut rng);
    let x = Matrix::randn(4, 64, &mut rng);
    let got = rt
        .execute_f32(
            "block_diag_b8",
            &[
                literal_from_blockdiag(&bd).unwrap(),
                literal_f32(&x.data, &[4, 64]).unwrap(),
            ],
        )
        .unwrap();
    let want = bd.matmul_rows(&x);
    assert_close(&got, &want.data, 1e-4, "block_diag_b8");
}

#[test]
fn monarch_kernel_matches_rust_n64() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(12);
    let m = MonarchMatrix::randn(8, &mut rng);
    let x = Matrix::randn(8, 64, &mut rng);
    let (l, r) = literals_from_monarch(&m).unwrap();
    let got = rt
        .execute_f32(
            "monarch_mvm_n64",
            &[l, r, literal_f32(&x.data, &[8, 64]).unwrap()],
        )
        .unwrap();
    let want = m.matmul_rows(&x);
    assert_close(&got, &want.data, 1e-4, "monarch_mvm_n64");
}

#[test]
fn monarch_kernel_matches_rust_n1024() {
    // BERT-scale d_model: the production tile size (b = 32).
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(13);
    let m = MonarchMatrix::randn(32, &mut rng);
    let x = Matrix::randn(4, 1024, &mut rng);
    let (l, r) = literals_from_monarch(&m).unwrap();
    let got = rt
        .execute_f32(
            "monarch_mvm_n1024",
            &[l, r, literal_f32(&x.data, &[4, 1024]).unwrap()],
        )
        .unwrap();
    let want = m.matmul_rows(&x);
    assert_close(&got, &want.data, 2e-3, "monarch_mvm_n1024");
}

#[test]
fn lane_sequential_kernel_matches_plain() {
    // DenseMap-ordered kernel == plain kernel == Rust reference.
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(14);
    let m = MonarchMatrix::randn(8, &mut rng);
    let x = Matrix::randn(8, 64, &mut rng);
    let (l, r) = literals_from_monarch(&m).unwrap();
    let got = rt
        .execute_f32(
            "monarch_mvm_lanes_n64",
            &[l, r, literal_f32(&x.data, &[8, 64]).unwrap()],
        )
        .unwrap();
    let want = m.matmul_rows(&x);
    assert_close(&got, &want.data, 1e-4, "monarch_mvm_lanes_n64");
}

#[test]
fn d2s_roundtrip_through_pjrt() {
    // Rust D2S projection -> factors fed to the AOT kernel -> result
    // close to the original dense matmul (within projection error).
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(15);
    let b = 8;
    // near-Monarch dense weight
    let base = MonarchMatrix::randn(b, &mut rng)
        .to_dense()
        .scale(1.0 / b as f32);
    let w = base.add(&Matrix::randn(64, 64, &mut rng).scale(0.01));
    let m = monarch_project(&w);
    let x = Matrix::randn(8, 64, &mut rng);
    let (l, r) = literals_from_monarch(&m).unwrap();
    let got = rt
        .execute_f32(
            "monarch_mvm_n64",
            &[l, r, literal_f32(&x.data, &[8, 64]).unwrap()],
        )
        .unwrap();
    // exact projected-operator reference
    let want_proj = m.matmul_rows(&x);
    assert_close(&got, &want_proj.data, 1e-4, "pjrt vs rust projected");
    // and close to the original dense operator
    let want_dense = x.matmul(&w.transpose());
    let got_m = Matrix::from_vec(8, 64, got);
    let rel = got_m.rel_error(&want_dense);
    assert!(rel < 0.2, "projected operator strayed too far: rel {rel}");
}

#[test]
fn adc_kernel_matches_rust_quantizer() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::new(16);
    let bd = BlockDiag::randn(8, 8, &mut rng);
    let x = Matrix::randn(4, 64, &mut rng);
    let got = rt
        .execute_f32(
            "block_diag_adc_b8",
            &[
                literal_from_blockdiag(&bd).unwrap(),
                literal_f32(&x.data, &[4, 64]).unwrap(),
            ],
        )
        .unwrap();
    // reference: exact block-diag then mid-tread 5b quantization @ fs=8
    let exact = bd.matmul_rows(&x);
    let want: Vec<f32> = exact
        .data
        .iter()
        .map(|&v| monarch_cim::cim::crossbar::quantize(v, 5, 8.0))
        .collect();
    assert_close(&got, &want, 1e-4, "block_diag_adc_b8");
}

#[test]
fn tiny_lm_matches_python_golden() {
    // The logits the JAX model produced at AOT time must be reproduced by
    // the PJRT-executed artifact, proving the full L1+L2 -> L3 path.
    let Some(mut rt) = runtime() else { return };
    let golden_text = match std::fs::read_to_string("artifacts/tiny_lm_golden.json") {
        Ok(t) => t,
        Err(e) => {
            eprintln!("SKIP: golden file missing ({e})");
            return;
        }
    };
    let golden = Json::parse(&golden_text).unwrap();
    let tokens: Vec<i32> = golden.get("tokens").unwrap().as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect();
    let logits = rt
        .execute_f32("tiny_lm_b1", &[literal_i32(&tokens, &[1, 32]).unwrap()])
        .unwrap();
    let want_sum = golden.get("logits_sum").unwrap().as_f64().unwrap();
    let got_sum: f64 = logits.iter().map(|&v| v as f64).sum();
    assert!(
        (got_sum - want_sum).abs() < 1e-1 * (1.0 + want_sum.abs()),
        "logits sum {got_sum} vs golden {want_sum}"
    );
    let first8 = golden.get("logits_first8").unwrap().as_arr().unwrap();
    for (i, g) in first8.iter().enumerate() {
        let w = g.as_f64().unwrap() as f32;
        assert!(
            (logits[i] - w).abs() < 1e-3 * (1.0 + w.abs()),
            "logit[{i}] {} vs {w}",
            logits[i]
        );
    }
}

#[test]
fn shape_validation_rejects_bad_feeds() {
    let Some(mut rt) = runtime() else { return };
    // wrong number of inputs
    assert!(rt.execute("monarch_mvm_n64", &[]).is_err());
    // wrong shape
    let bad = literal_f32(&[0.0; 16], &[4, 4]).unwrap();
    let bad2 = literal_f32(&[0.0; 16], &[4, 4]).unwrap();
    let err = match rt.execute("block_diag_b8", &[bad, bad2]) {
        Err(e) => e,
        Ok(_) => panic!("bad shapes must be rejected"),
    };
    assert!(err.to_string().contains("expected"), "{err}");
    // unknown artifact
    assert!(rt.execute("nope", &[]).is_err());
}
