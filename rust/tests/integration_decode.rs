//! Integration: end-to-end autoregressive decode on the emulated CIM
//! chip — the tier-1 correctness contract of `sim::decode`.
//!
//! * Greedy token sequences must be identical across Linear, SparseMap
//!   and DenseMap, and identical to the factored reference model.
//! * SparseMap/DenseMap per-position logits must match the reference
//!   within 1e-5 max abs diff (they are in fact bit-identical: the chip
//!   replays the reference's f32 operations in the same order).
//! * Per-token modeled cost must be positive and grow with the KV cache.
//! * The CIM-sim serving backend must batch, validate and stay
//!   deterministic without any PJRT artifacts.

use monarch_cim::cim::CimParams;
use monarch_cim::coordinator::batching::BatchPolicy;
use monarch_cim::coordinator::{Backend, CimSimConfig, InferenceServer, ServerConfig};
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::sim::decode::{DecodeEngine, DecodeModel, DecodeResult};
use monarch_cim::util::rng::Pcg32;

const SEED: u64 = 2025;
const PROMPT: [i32; 4] = [11, 48, 85, 122];
// Fill the tiny model's context window exactly: prompt + generation must
// fit `seq` (32) — requests beyond it are now rejected at admission
// instead of silently clamping the position (ISSUE 4).
const TOKENS: usize = 28;

fn tiny() -> ModelConfig {
    ModelConfig::tiny()
}

fn chip_engine(strategy: Strategy) -> DecodeEngine {
    DecodeEngine::on_chip(
        DecodeModel::synth(tiny(), SEED),
        CimParams::default(),
        strategy,
    )
}

fn reference_engine() -> DecodeEngine {
    DecodeEngine::reference(DecodeModel::synth(tiny(), SEED))
}

#[test]
fn greedy_sequences_identical_across_strategies() {
    let golden: DecodeResult = reference_engine().generate(&PROMPT, TOKENS);
    assert_eq!(golden.tokens.len(), TOKENS);
    for strategy in Strategy::all() {
        let r = chip_engine(strategy).generate(&PROMPT, TOKENS);
        assert_eq!(
            r.tokens, golden.tokens,
            "{strategy:?} diverged from the reference token sequence"
        );
    }
}

#[test]
fn monarch_strategies_match_reference_logits_within_1e5() {
    let window: Vec<i32> = {
        let mut g = reference_engine();
        let r = g.generate(&PROMPT, TOKENS);
        PROMPT.iter().chain(&r.tokens).copied().collect()
    };
    let (ref_logits, _) = reference_engine().score(&window);
    for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
        let (chip_logits, _) = chip_engine(strategy).score(&window);
        let max_diff = chip_logits
            .iter()
            .zip(&ref_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff <= 1e-5,
            "{strategy:?}: max |logit diff| {max_diff} > 1e-5"
        );
    }
    // Linear programs the dense materialization of the same operator —
    // equal tokens, float-tolerance logits.
    let (lin_logits, _) = chip_engine(Strategy::Linear).score(&window);
    let max_diff = lin_logits
        .iter()
        .zip(&ref_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff <= 1e-2,
        "Linear baseline strayed too far from the operator it stores: {max_diff}"
    );
}

#[test]
fn per_token_costs_positive_and_kv_monotone() {
    for strategy in Strategy::all() {
        let mut eng = chip_engine(strategy);
        let r = eng.generate(&PROMPT, 8);
        assert_eq!(r.per_token.len(), PROMPT.len() + 8);
        for c in &r.per_token {
            assert!(c.latency.critical_ns() > 0.0, "{strategy:?}: zero latency");
            assert!(c.energy.total_nj() > 0.0, "{strategy:?}: zero energy");
        }
        // MHA work grows strictly with the cache; the Para path is flat
        let mha: Vec<f64> = r.per_token.iter().map(|c| c.latency.mha_ns).collect();
        assert!(
            mha.windows(2).all(|w| w[1] > w[0]),
            "{strategy:?}: MHA cost not monotone: {mha:?}"
        );
        let adc: Vec<f64> = r.per_token.iter().map(|c| c.latency.adc_ns).collect();
        assert!(adc.windows(2).all(|w| (w[1] - w[0]).abs() < 1e-9));
    }
}

#[test]
fn decode_is_deterministic_across_engine_instances() {
    for strategy in Strategy::all() {
        let a = chip_engine(strategy).generate(&PROMPT, 12);
        let b = chip_engine(strategy).generate(&PROMPT, 12);
        assert_eq!(a.tokens, b.tokens, "{strategy:?} not deterministic");
    }
}

#[test]
fn cimsim_server_serves_batches_without_artifacts() {
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            strategy: Strategy::DenseMap,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: std::time::Duration::from_millis(20),
        },
        ..Default::default()
    })
    .expect("CIM-sim server must start with no artifacts");
    let seq = server.seq;
    let vocab = server.vocab;
    std::thread::scope(|scope| {
        for i in 0..8u64 {
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Pcg32::new(i);
                let toks: Vec<i32> =
                    (0..seq).map(|_| rng.below(vocab as u32) as i32).collect();
                let logits = srv.infer(toks).expect("inference");
                assert_eq!(logits.len(), seq * vocab);
                assert!(logits.iter().all(|v| v.is_finite()));
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 8);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.sim_tokens, 8 * seq as u64);
    assert!(snap.sim_token_latency_ns > 0.0);
    assert!(snap.sim_energy_nj > 0.0);
    server.shutdown();
}

#[test]
fn cimsim_server_matches_local_engine() {
    // The serving path must produce exactly what a local engine computes
    // (same seed, same strategy) — no batching contamination.
    let server = InferenceServer::start(ServerConfig::cim_sim(Strategy::SparseMap))
        .expect("server start");
    let seq = server.seq;
    let toks: Vec<i32> = (0..seq).map(|i| ((i * 7 + 3) % server.vocab) as i32).collect();
    let served = server.infer(toks.clone()).unwrap();
    server.shutdown();
    let mut local = DecodeEngine::on_chip(
        DecodeModel::synth(tiny(), SEED),
        CimParams::default(),
        Strategy::SparseMap,
    );
    let (want, _) = local.score(&toks);
    assert_eq!(served, want, "served logits differ from the local engine");
}
