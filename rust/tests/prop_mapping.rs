//! Property tests: mapping invariants across strategies, model shapes and
//! array geometries — block conservation, placement disjointness,
//! rotation pairing, utilization bounds.

use monarch_cim::mapping::rotation::{is_self_inverse, net_rotation};
use monarch_cim::mapping::{map_ops, Factor, Strategy};
use monarch_cim::model::{MatmulOp, ModelConfig, OpKind, Stage};
use monarch_cim::util::prop::forall;

mod common;

/// Random op list over square-ish shapes that divide into d tiles.
/// Deliberately NOT `common::random_model_ops`: this one draws ragged
/// rectangular shapes with batch 8, stressing the packers rather than
/// the transformer layer pattern.
fn gen_ops(g: &mut monarch_cim::util::prop::Gen, d: usize) -> Vec<MatmulOp> {
    let n_ops = g.usize(1, 6);
    (0..n_ops)
        .map(|i| {
            let rows_mult = g.usize(1, 4);
            let cols_mult = g.usize(1, 4);
            let kinds = ["wq", "wk", "wv", "wo", "ffn1", "ffn2"];
            MatmulOp {
                name: format!("dec{}.{}", i / 6, kinds[i % 6]),
                stage: Stage::Decoder,
                layer: i / 6,
                kind: OpKind::Para,
                rows: rows_mult * d,
                cols: cols_mult * d,
                batch: 8,
            }
        })
        .collect()
}

fn tiny_cfg(d: usize) -> ModelConfig {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = d;
    cfg
}

#[test]
fn prop_blocks_conserved_all_strategies() {
    forall("blocks conserved", 25, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let params = common::chip_params(g, &[16, 32, 64]);
        if b > params.array_dim {
            return;
        }
        let cfg = tiny_cfg(d);
        let ops = gen_ops(g, d);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mm = map_ops(&cfg, &ops, &params, strategy);
            let placed: usize = mm.placements.iter().map(|p| p.blocks).sum();
            let want: usize = ops
                .iter()
                .map(|o| (o.rows.div_ceil(d) * o.cols.div_ceil(d)) * 2 * b)
                .sum();
            assert_eq!(placed, want, "{strategy:?}");
        }
    });
}

#[test]
fn prop_dense_diagonals_never_collide() {
    forall("diag slots unique per array", 25, |g| {
        let d = g.choose(&[16usize, 64]);
        let params = common::chip_params(g, &[16, 32, 64]);
        if (d as f64).sqrt() as usize > params.array_dim {
            return;
        }
        let cfg = tiny_cfg(d);
        let ops = gen_ops(g, d);
        let mm = map_ops(&cfg, &ops, &params, Strategy::DenseMap);
        let mut seen = std::collections::HashSet::new();
        for p in &mm.placements {
            assert!(
                seen.insert((p.array, p.diag)),
                "array {} diag {} double-booked",
                p.array,
                p.diag
            );
        }
    });
}

#[test]
fn prop_dense_rotation_pairs_cancel() {
    forall("rotation pairing", 25, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let params = common::chip_params(g, &[16, 32, 64]);
        let m = params.array_dim;
        if b > m {
            return;
        }
        let cfg = tiny_cfg(d);
        let ops = gen_ops(g, d);
        let mm = map_ops(&cfg, &ops, &params, Strategy::DenseMap);
        let lanes = m / b;
        let mut pairs: std::collections::HashMap<(usize, usize, usize), Vec<&_>> =
            std::collections::HashMap::new();
        for p in &mm.placements {
            pairs.entry((p.op, p.tile, p.lane_of_factor)).or_default().push(p);
        }
        for (key, ps) in pairs {
            assert_eq!(ps.len(), 2, "incomplete pair {key:?}");
            let (l, r) = if ps[0].factor == Factor::Left {
                (ps[0], ps[1])
            } else {
                (ps[1], ps[0])
            };
            assert_eq!(l.factor, Factor::Left);
            assert_eq!(r.factor, Factor::Right);
            assert_eq!(
                net_rotation(l.diag, r.diag, lanes),
                0,
                "rotation uncancelled at {key:?}"
            );
            if is_self_inverse(l.diag, lanes) {
                assert_ne!(l.array, r.array, "self-inverse pair co-resident");
            } else {
                assert_eq!(l.array, r.array, "complementary pair split");
            }
        }
    });
}

#[test]
fn prop_utilization_ordering() {
    // DenseMap util >= SparseMap util; arrays(Dense) <= arrays(Sparse)
    // <= arrays(Linear), for every geometry.
    forall("utilization ordering", 20, |g| {
        let d = g.choose(&[16usize, 64]);
        let params = common::chip_params(g, &[32, 64, 256]);
        if (d as f64).sqrt() as usize > params.array_dim {
            return;
        }
        let cfg = tiny_cfg(d);
        let ops = gen_ops(g, d);
        let lin = map_ops(&cfg, &ops, &params, Strategy::Linear);
        let sp = map_ops(&cfg, &ops, &params, Strategy::SparseMap);
        let de = map_ops(&cfg, &ops, &params, Strategy::DenseMap);
        assert!(de.arrays <= sp.arrays, "dense {} sparse {}", de.arrays, sp.arrays);
        // SparseMap needs at most 2 arrays per d-tile (L + R factors) and
        // Linear at least one array per op; no tighter universal bound
        // holds when d << m (Linear packs a whole weight in one array).
        let tiles: usize = ops
            .iter()
            .map(|o| o.rows.div_ceil(d) * o.cols.div_ceil(d))
            .sum();
        assert!(sp.arrays <= 2 * tiles * ((d as f64).sqrt() as usize), "sparse bound");
        assert!(lin.arrays >= ops.len());
        assert!(de.utilization() + 1e-9 >= sp.utilization());
        for mm in [&lin, &sp, &de] {
            assert!(mm.utilization() <= 1.0 + 1e-9);
        }
    });
}

#[test]
fn prop_sparse_utilization_formula() {
    // For full lanes, SparseMap utilization == b/m exactly.
    forall("sparse util == b/m", 15, |g| {
        let d = 64; // b = 8
        let cfg = tiny_cfg(d);
        let params = common::chip_params(g, &[32, 64, 256]);
        let m = params.array_dim;
        // ops sized so every lane fills completely: rows=cols=d and
        // b % (m/b) == 0
        let b = 8usize;
        if b % (m / b).min(b) != 0 {
            return;
        }
        let ops = gen_ops(g, d);
        let mm = map_ops(&cfg, &ops, &params, Strategy::SparseMap);
        let want = b as f64 / m as f64;
        assert!(
            (mm.utilization() - want).abs() < 0.05,
            "util {} vs b/m {want}",
            mm.utilization()
        );
    });
}
