//! Statistical property suite for noise/ADC-aware analog decode
//! (DESIGN.md §6i): over random decoder geometries, chip parameters and
//! mapping strategies,
//!
//! 1. **ideal analog mode is bit-identical to the exact path** — tokens,
//!    logits and KV contents — on the single-stream, batched AND
//!    layer-sharded engines (bit-identity holds by construction:
//!    corruption is gated off, and a cap at or above the required
//!    resolution never quantizes);
//! 2. **same seed ⇒ same chip** — two independently programmed noisy
//!    chips corrupt identically (`Pcg32::stream(seed, array)`), so
//!    analog decode is reproducible run-to-run;
//! 3. **divergence is zero at ideal settings and non-decreasing in
//!    `write_sigma`** on a fixed seed ladder — the same per-cell error
//!    direction scaled up can only push the logit stream further off.

use monarch_cim::cim::{AnalogMode, PcmNoise};
use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::sim::measure_divergence;
use monarch_cim::util::prop::forall;

mod common;

fn prompt_of(len: usize, salt: usize, vocab: usize) -> Vec<i32> {
    (0..len)
        .map(|i| ((i * 7 + salt * 31 + 3) % vocab) as i32)
        .collect()
}

#[test]
fn prop_ideal_analog_bit_identical_single_stream() {
    forall("ideal analog == exact (single stream)", 8, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let prompt = prompt_of(g.usize(1, 4), 0, cfg.vocab);
        let n_tokens = g.usize(1, 4);
        let mut exact = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        assert!(exact.analog_mode().is_none(), "plain engine has no mode");
        // both ideal spellings must be exact: no analog state at all is
        // trivially exact; an 8-bit cap can never sit below the required
        // resolution (required_bits clamps to adc_ref_bits = 8)
        for mode in [
            AnalogMode::ideal(),
            AnalogMode {
                adc_bits: Some(8),
                ..AnalogMode::ideal()
            },
        ] {
            let mut analog = DecodeEngine::on_chip_analog(
                DecodeModel::synth(cfg.clone(), seed),
                params.clone(),
                strategy,
                Some(&mode),
            );
            assert!(analog.analog_mode().is_some(), "mode must be recorded");
            let a = exact.generate(&prompt, n_tokens);
            let b = analog.generate(&prompt, n_tokens);
            assert_eq!(
                a.tokens, b.tokens,
                "{strategy:?} ideal analog tokens diverged"
            );
            let window: Vec<i32> = prompt.iter().chain(&a.tokens).copied().collect();
            let d = measure_divergence(&mut exact, &mut analog, &window);
            assert!(d.is_exact(), "{strategy:?} ideal divergence: {d:?}");
            let (le, _) = exact.score(&window);
            let (la, _) = analog.score(&window);
            for (p, (x, y)) in le.iter().zip(&la).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{strategy:?} logit {p} not bitwise equal"
                );
            }
        }
    });
}

#[test]
fn prop_ideal_analog_bit_identical_batched_and_sharded() {
    forall("ideal analog == exact (batched + sharded)", 6, |g| {
        let mut cfg = common::random_decoder_cfg(g);
        cfg.dec_layers = g.usize(1, 4); // deeper: real multi-stage splits
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let capacity = g.usize(1, 3);
        let shards = g.usize(1, 4);
        let n_tokens = g.usize(1, 3);
        let ideal = AnalogMode::ideal();
        let prompts: Vec<Vec<i32>> = (0..capacity + g.usize(0, 2))
            .map(|r| prompt_of(g.usize(1, 4), r, cfg.vocab))
            .collect();
        let mut exact = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        let mut analog = BatchDecodeEngine::on_chip_analog(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
            Some(&ideal),
        );
        let want = exact.generate_batch(&prompts, n_tokens);
        let got = analog.generate_batch(&prompts, n_tokens);
        for (ri, (w, a)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                w.tokens, a.tokens,
                "{strategy:?} request {ri}: batched ideal analog diverged"
            );
        }
        let mut sharded_exact = BatchDecodeEngine::sharded(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
            shards,
        );
        let mut sharded_analog = BatchDecodeEngine::sharded_analog(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
            shards,
            Some(&ideal),
        );
        // step-level: logits and full KV bitwise across the shard stack
        let slots: Vec<usize> = (0..capacity)
            .map(|_| {
                let a = sharded_exact.try_admit().unwrap();
                let b = sharded_analog.try_admit().unwrap();
                assert_eq!(a, b, "fresh pools hand out the same slots");
                a
            })
            .collect();
        let mut fed = vec![0usize; capacity];
        for _step in 0..g.usize(1, 3) {
            let mut chunks: Vec<Vec<i32>> = Vec::with_capacity(capacity);
            for (s, f) in fed.iter_mut().enumerate() {
                let room = cfg.seq - *f; // never 0: <=9 tokens fed into seq 16
                let c = g.usize(1, 3).min(room);
                chunks.push(
                    (0..c)
                        .map(|i| ((s * 13 + (*f + i) * 5 + 2) % cfg.vocab) as i32)
                        .collect(),
                );
                *f += c;
            }
            let groups: Vec<(usize, &[i32])> = slots
                .iter()
                .zip(&chunks)
                .map(|(&s, c)| (s, &c[..]))
                .collect();
            sharded_exact.step_chunks(&groups);
            sharded_analog.step_chunks(&groups);
            for &s in &slots {
                assert_eq!(
                    sharded_exact.logits(s),
                    sharded_analog.logits(s),
                    "{strategy:?} shards {shards} slot {s}: ideal analog logits drift"
                );
            }
        }
        for &s in &slots {
            assert_eq!(sharded_exact.kv_len(s), sharded_analog.kv_len(s));
            for l in 0..cfg.dec_layers {
                for pos in 0..sharded_exact.kv_len(s) {
                    assert_eq!(
                        sharded_exact.kv(s).key(l, pos),
                        sharded_analog.kv(s).key(l, pos),
                        "{strategy:?} slot {s} layer {l} pos {pos}: key drift"
                    );
                    assert_eq!(
                        sharded_exact.kv(s).value(l, pos),
                        sharded_analog.kv(s).value(l, pos),
                        "{strategy:?} slot {s} layer {l} pos {pos}: value drift"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_same_seed_noisy_decode_is_reproducible() {
    forall("same analog seed -> bitwise identical decode", 8, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let mode = AnalogMode {
            noise: PcmNoise {
                write_sigma: 0.01 + 0.01 * g.usize(0, 4) as f64,
                drift_nu: 0.05,
                drift_time_ratio: 100.0,
            },
            adc_bits: g.choose(&[None, Some(2), Some(4)]),
            seed: common::seed(g),
        };
        let prompt = prompt_of(g.usize(1, 4), 1, cfg.vocab);
        let n_tokens = g.usize(1, 4);
        // two engines programmed independently from the same weights and
        // the same analog seed must agree bit for bit
        let mut a = DecodeEngine::on_chip_analog(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            Some(&mode),
        );
        let mut b = DecodeEngine::on_chip_analog(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            Some(&mode),
        );
        let ra = a.generate(&prompt, n_tokens);
        let rb = b.generate(&prompt, n_tokens);
        assert_eq!(
            ra.tokens, rb.tokens,
            "{strategy:?} same-seed noisy decode not reproducible"
        );
        let window: Vec<i32> = prompt.iter().chain(&ra.tokens).copied().collect();
        let (la, _) = a.score(&window);
        let (lb, _) = b.score(&window);
        for (p, (x, y)) in la.iter().zip(&lb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{strategy:?} logit {p}: same-seed streams not bitwise equal"
            );
        }
    });
}

#[test]
fn prop_divergence_zero_at_ideal_and_nondecreasing_in_sigma() {
    forall("divergence: 0 at ideal, grows with sigma", 6, |g| {
        let mut cfg = common::random_decoder_cfg(g);
        cfg.dec_layers = 1; // shallow: keeps the response near-linear
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let noise_seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let window = prompt_of(4, 2, cfg.vocab);
        let mut exact = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        // fixed-seed ladder: every rung draws the SAME per-cell error
        // direction (sigma only scales it), so the logit error can only
        // grow as sigma does
        let mut prev = 0.0f64;
        for sigma in [0.0, 0.005, 0.02, 0.08] {
            let mode = AnalogMode {
                noise: PcmNoise {
                    write_sigma: sigma,
                    drift_nu: 0.0,
                    drift_time_ratio: 1.0,
                },
                adc_bits: None,
                seed: noise_seed,
            };
            let mut analog = DecodeEngine::on_chip_analog(
                DecodeModel::synth(cfg.clone(), seed),
                params.clone(),
                strategy,
                Some(&mode),
            );
            let d = measure_divergence(&mut exact, &mut analog, &window);
            if sigma == 0.0 {
                assert!(d.is_exact(), "{strategy:?} sigma=0 diverged: {d:?}");
            } else {
                assert!(
                    d.max_abs_logit_err > 0.0,
                    "{strategy:?} sigma={sigma} left the logits untouched"
                );
                assert!(
                    d.rms_logit_err >= prev,
                    "{strategy:?} sigma={sigma}: rms {} fell below {prev}",
                    d.rms_logit_err
                );
            }
            prev = d.rms_logit_err;
        }
    });
}
