//! Property tests for the layer-sharded pipeline engine (`sim::shard`,
//! ISSUE 7): over random model geometries, mapping strategies, shard
//! counts 1..=4 and ragged batches, a sharded [`BatchDecodeEngine`] is
//! **bitwise equal** to the single-chip engine — tokens, logits AND KV
//! contents.
//!
//! Why this must hold: the functional sharded step runs every stage in
//! layer order over the step's lanes, so each lane replays exactly the
//! f32 operations of the single-chip path; the only thing sharding
//! changes is *which chip's pass tables* execute a layer, and a chip's
//! replay of an op is independent of what else is programmed beside it
//! (the `prop_exec_plan` invariant). The pipeline overlap lives purely
//! in the latency model (`trace::pipeline_timeline`).

use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeModel};
use monarch_cim::sim::stage_ranges;
use monarch_cim::util::prop::forall;

mod common;

#[test]
fn prop_sharded_generate_equals_single_chip() {
    forall("sharded generate == single-chip generate", 6, |g| {
        let mut cfg = common::random_decoder_cfg(g);
        // deeper models so shards 1..=4 exercises real multi-stage
        // splits (stage_ranges clamps oversharded cases regardless)
        cfg.dec_layers = g.usize(1, 5);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let shards = g.usize(1, 4);
        let capacity = g.usize(1, 4);
        let n_requests = capacity + g.usize(0, 2);
        let n_tokens = g.usize(1, 4);
        let chunk = g.usize(1, 4); // chunked prefill rides the pipeline too
        let prompts: Vec<Vec<i32>> = (0..n_requests)
            .map(|r| {
                let len = g.usize(1, 5); // ragged prompt lengths
                (0..len)
                    .map(|i| ((r * 31 + i * 7 + 3) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let mut sharded = BatchDecodeEngine::sharded(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
            shards,
        );
        assert_eq!(sharded.stage_count(), shards.clamp(1, cfg.dec_layers));
        let piped = sharded.generate_batch_chunked(&prompts, n_tokens, chunk);
        let mut mono = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        let want = mono.generate_batch_chunked(&prompts, n_tokens, chunk);
        for (ri, (a, w)) in piped.iter().zip(&want).enumerate() {
            assert_eq!(
                a.tokens, w.tokens,
                "{strategy:?} shards {shards} request {ri}: sharded tokens \
                 diverged from the single-chip engine"
            );
            // per-position costs are priced with the sharded engine's
            // stored 1-chip reference mapping, so they must be exactly
            // the mono engine's records
            assert_eq!(a.per_token.len(), w.per_token.len());
            for (i, (ac, wc)) in a.per_token.iter().zip(&w.per_token).enumerate() {
                assert_eq!(
                    ac.latency.critical_ns(),
                    wc.latency.critical_ns(),
                    "{strategy:?} shards {shards} request {ri} position {i}: cost drift"
                );
                assert_eq!(ac.energy.total_nj(), wc.energy.total_nj());
            }
        }
        // the pipeline accumulator saw every step
        let ps = sharded.pipeline_stats();
        assert!(ps.steps > 0, "sharded steps must record timelines");
        assert_eq!(ps.stage_busy_ns.len(), sharded.stage_count());
        assert!(ps.span_ns.is_finite() && ps.span_ns > 0.0);
        let bubble = ps.bubble_fraction();
        assert!((0.0..=1.0).contains(&bubble), "bubble {bubble} out of range");
        assert!(ps.speedup_vs_1chip().is_finite() && ps.speedup_vs_1chip() > 0.0);
    });
}

#[test]
fn prop_sharded_step_logits_and_kv_bitwise() {
    // Step-level check with mixed decode/prefill lanes: after every
    // shared step, each lane's logits and every slot's full KV cache
    // are bitwise the single-chip engine's.
    forall("sharded step logits+KV == single-chip", 6, |g| {
        let mut cfg = common::random_decoder_cfg(g);
        cfg.dec_layers = g.usize(1, 5);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let shards = g.usize(1, 4);
        let capacity = g.usize(1, 3);
        let mut sharded = BatchDecodeEngine::sharded(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
            shards,
        );
        let mut mono = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        let slots: Vec<usize> = (0..capacity)
            .map(|_| {
                let a = sharded.try_admit().unwrap();
                let b = mono.try_admit().unwrap();
                assert_eq!(a, b, "fresh pools hand out the same slots");
                a
            })
            .collect();
        let steps = g.usize(1, 3);
        let mut fed = vec![0usize; capacity];
        for step in 0..steps {
            // ragged chunks: each slot advances 1..=3 positions (decode
            // lanes are chunks of 1, prefill lanes wider), bounded by
            // the context window
            let mut chunks: Vec<Vec<i32>> = Vec::with_capacity(capacity);
            for (s, f) in fed.iter_mut().enumerate() {
                let room = cfg.seq - *f;
                let c = g.usize(1, 3).min(room).max(1);
                chunks.push(
                    (0..c)
                        .map(|i| ((s * 13 + (*f + i) * 5 + 2) % cfg.vocab) as i32)
                        .collect(),
                );
                *f += c;
            }
            let groups: Vec<(usize, &[i32])> = slots
                .iter()
                .zip(&chunks)
                .map(|(&s, c)| (s, &c[..]))
                .collect();
            sharded.step_chunks(&groups);
            mono.step_chunks(&groups);
            // lane-by-lane logits of this step
            let lanes: usize = chunks.iter().map(|c| c.len()).sum();
            for lane in 0..lanes {
                assert_eq!(
                    sharded.lane_logits(lane),
                    mono.lane_logits(lane),
                    "{strategy:?} shards {shards} step {step} lane {lane}: logits drift"
                );
            }
            for &s in &slots {
                assert_eq!(
                    sharded.logits(s),
                    mono.logits(s),
                    "{strategy:?} shards {shards} step {step} slot {s}: logits drift"
                );
            }
        }
        // full KV contents, every layer, every position, bitwise
        for &s in &slots {
            assert_eq!(sharded.kv_len(s), mono.kv_len(s));
            for l in 0..cfg.dec_layers {
                for pos in 0..sharded.kv_len(s) {
                    assert_eq!(
                        sharded.kv(s).key(l, pos),
                        mono.kv(s).key(l, pos),
                        "{strategy:?} shards {shards} slot {s} layer {l} pos {pos}: key drift"
                    );
                    assert_eq!(
                        sharded.kv(s).value(l, pos),
                        mono.kv(s).value(l, pos),
                        "{strategy:?} shards {shards} slot {s} layer {l} pos {pos}: value drift"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_stage_ranges_partition() {
    forall("stage_ranges covers contiguously", 12, |g| {
        let n_layers = g.usize(1, 48);
        let shards = g.usize(0, 12);
        let ranges = stage_ranges(n_layers, shards);
        assert_eq!(ranges.len(), shards.clamp(1, n_layers));
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, n_layers);
        let mut depths = Vec::new();
        for (i, w) in ranges.windows(2).enumerate() {
            assert_eq!(w[0].1, w[1].0, "gap/overlap between stages {i} and {}", i + 1);
        }
        for &(lo, hi) in &ranges {
            assert!(hi > lo, "empty stage [{lo}..{hi})");
            depths.push(hi - lo);
        }
        let (min, max) = (
            *depths.iter().min().unwrap(),
            *depths.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "near-even split violated: {depths:?}");
    });
}
