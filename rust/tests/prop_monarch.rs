//! Property tests: Monarch algebra invariants (heavier case counts than
//! the in-module tests; uses the repo's mini property harness). Weight
//! seeds are drawn through `common::seed` so failures replay from the
//! `forall` seed report like every other suite.

use monarch_cim::monarch::{
    monarch_project, FoldedMonarch, MonarchMatrix, RectMonarch, StridePerm,
};
use monarch_cim::tensor::Matrix;
use monarch_cim::util::prop::forall;
use monarch_cim::util::rng::Pcg32;

mod common;

#[test]
fn prop_projection_is_idempotent() {
    // proj(proj(W)) == proj(W): the projection lands in the Monarch class
    // and projecting a Monarch matrix recovers it.
    forall("projection idempotent", 25, |g| {
        let b = g.usize(2, 6);
        let n = b * b;
        let data = g.normal_vec(n * n);
        let w = Matrix::from_vec(n, n, data);
        let once = monarch_project(&w).to_dense();
        let twice = monarch_project(&once).to_dense();
        assert!(
            twice.rel_error(&once) < 1e-3,
            "idempotence violated: {}",
            twice.rel_error(&once)
        );
    });
}

#[test]
fn prop_projection_error_never_increases_with_structure() {
    // Interpolating toward the Monarch class never increases error.
    forall("error monotone in structure", 15, |g| {
        let b = g.usize(2, 5);
        let n = b * b;
        let mut rng = Pcg32::new(common::seed(g));
        let m = MonarchMatrix::randn(b, &mut rng).to_dense();
        let noise = Matrix::randn(n, n, &mut rng);
        let err_at = |alpha: f32| {
            let w = m.scale(1.0 - alpha).add(&noise.scale(alpha));
            monarch_project(&w).to_dense().rel_error(&w)
        };
        let e_low = err_at(0.1);
        let e_high = err_at(0.9);
        assert!(
            e_low <= e_high + 0.02,
            "structure monotonicity: {e_low} vs {e_high}"
        );
    });
}

#[test]
fn prop_monarch_composition_via_permutation() {
    // y = P L P R P x computed factored == dense M @ x, across sizes.
    forall("factored == dense", 30, |g| {
        let b = g.usize(2, 8);
        let mut rng = Pcg32::new(common::seed(g));
        let m = MonarchMatrix::randn(b, &mut rng);
        let x = rng.normal_vec(m.n());
        let got = m.matvec(&x);
        let want = m.to_dense().matvec(&x);
        for (a, w) in got.iter().zip(&want) {
            assert!((a - w).abs() < 2e-3 * (1.0 + w.abs()));
        }
    });
}

#[test]
fn prop_folding_preserves_operator() {
    forall("fold == unfold", 30, |g| {
        let b = g.usize(2, 8);
        let mut rng = Pcg32::new(common::seed(g));
        let m = MonarchMatrix::randn(b, &mut rng);
        let f = FoldedMonarch::from_monarch(&m);
        let x = rng.normal_vec(m.n());
        let a = m.matvec(&x);
        let c = f.matvec(&x);
        for (p, q) in a.iter().zip(&c) {
            assert!((p - q).abs() < 2e-3 * (1.0 + q.abs()));
        }
    });
}

#[test]
fn prop_permutation_group_structure() {
    forall("P^2 = I and P orthogonal", 40, |g| {
        let b = g.usize(1, 12);
        let p = StridePerm::new(b);
        // involution on indices
        for i in 0..p.n() {
            assert_eq!(p.map(p.map(i)), i);
        }
        // preserves inner products (orthogonality) on a random pair
        let x = g.normal_vec(p.n());
        let y = g.normal_vec(p.n());
        let dot = |a: &[f32], c: &[f32]| -> f64 {
            a.iter().zip(c).map(|(u, v)| (*u as f64) * (*v as f64)).sum()
        };
        let d1 = dot(&x, &y);
        let d2 = dot(&p.apply(&x), &p.apply(&y));
        assert!((d1 - d2).abs() < 1e-3 * (1.0 + d1.abs()));
    });
}

#[test]
fn prop_rect_tiling_matches_dense() {
    forall("rect monarch == densified", 10, |g| {
        let n = 16;
        let tr = g.usize(1, 3);
        let tc = g.usize(1, 3);
        let mut rng = Pcg32::new(common::seed(g));
        let w = Matrix::randn(tr * n, tc * n, &mut rng);
        let rect = RectMonarch::from_dense(&w, n);
        let x = rng.normal_vec(tc * n);
        let got = rect.matvec(&x);
        let want = rect.to_dense().matvec(&x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
        }
    });
}

#[test]
fn prop_params_always_subquadratic() {
    forall("monarch params < dense for b >= 3", 20, |g| {
        let b = g.usize(3, 16);
        let mut rng = Pcg32::new(1);
        let m = MonarchMatrix::randn(b, &mut rng);
        assert!(m.params() < m.n() * m.n());
        assert_eq!(m.params(), 2 * b * b * b);
    });
}
