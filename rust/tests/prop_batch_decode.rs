//! Property tests for batched decode (`sim::decode::BatchDecodeEngine`):
//! over random model geometries, mapping strategies, batch sizes 1..8
//! and ragged prompt lengths — including mid-run slot eviction and
//! admission (more requests than slots) — the batched engine is
//! **bit-identical** to B independent single-stream [`DecodeEngine`]s.
//!
//! This is the ISSUE-3 acceptance property: a slot's logits (and hence
//! its greedy tokens and per-position cost records) never depend on its
//! batchmates, because every lane of `run_op_batch_into` replays exactly
//! the f32 operations of the single-stream compiled plan.

use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::util::prop::forall;

mod common;

#[test]
fn prop_batched_generate_equals_independent_engines() {
    forall("batched decode == B single-stream engines", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let capacity = g.usize(1, 8);
        // more requests than slots exercises mid-run eviction+admission
        let n_requests = capacity + g.usize(0, 3);
        let n_tokens = g.usize(1, 4);
        let prompts: Vec<Vec<i32>> = (0..n_requests)
            .map(|r| {
                let len = g.usize(1, 5); // ragged prompt lengths
                (0..len)
                    .map(|i| ((r * 31 + i * 7 + 3) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        let mut batched = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        let results = batched.generate_batch(&prompts, n_tokens);
        assert_eq!(results.len(), n_requests);
        assert_eq!(batched.occupancy(), 0, "all slots evicted after the run");
        // one single-stream engine, reset per request (reuse-hardened),
        // must reproduce every stream token-for-token
        let mut single = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        for (ri, (p, r)) in prompts.iter().zip(&results).enumerate() {
            let want = single.generate(p, n_tokens);
            assert_eq!(
                r.tokens, want.tokens,
                "{strategy:?} capacity {capacity} request {ri}: batched tokens \
                 diverged from an independent engine"
            );
            assert_eq!(
                r.per_token.len(),
                want.per_token.len(),
                "{strategy:?} request {ri}: per-position cost count"
            );
            // modeled costs are a pure function of (cfg, mapping, kv_len)
            // so they must agree position by position too
            for (i, (a, w)) in r.per_token.iter().zip(&want.per_token).enumerate() {
                assert_eq!(
                    a.latency.critical_ns(),
                    w.latency.critical_ns(),
                    "{strategy:?} request {ri} position {i}: cost drift"
                );
            }
        }
    });
}

#[test]
fn prop_teacher_forced_logits_bit_identical() {
    // Step-level check: ragged slots stepped together produce, at every
    // position, logits bit-identical to single-stream forwards — even
    // with a mid-run eviction + admission into the freed slot.
    forall("teacher-forced batched logits == single-stream", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::monarch_strategy(g);
        let capacity = g.usize(2, 4);
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            capacity,
        );
        // admit `capacity` sequences of ragged lengths
        let lens: Vec<usize> = (0..capacity).map(|_| g.usize(2, 6)).collect();
        let seqs: Vec<Vec<i32>> = lens
            .iter()
            .enumerate()
            .map(|(s, &len)| {
                (0..len).map(|i| ((s * 17 + i * 5 + 1) % cfg.vocab) as i32).collect()
            })
            .collect();
        let slots: Vec<usize> = (0..capacity).map(|_| be.try_admit().unwrap()).collect();
        let mut singles: Vec<DecodeEngine> = (0..capacity)
            .map(|_| {
                DecodeEngine::on_chip(
                    DecodeModel::synth(cfg.clone(), seed),
                    params.clone(),
                    strategy,
                )
            })
            .collect();
        let max_len = *lens.iter().max().unwrap();
        let mut replacement: Option<(usize, Vec<i32>, DecodeEngine)> = None;
        for t in 0..max_len {
            // build this step's ragged input set (slots finish early)
            let mut inputs = Vec::new();
            for (i, seq) in seqs.iter().enumerate() {
                if t < seq.len() {
                    inputs.push((slots[i], seq[t]));
                }
            }
            // once the shortest sequence finished, evict it and admit a
            // fresh one mid-run into the freed slot
            if let Some((rs, rseq, _)) = &replacement {
                let pos = t - lens.iter().copied().min().unwrap();
                if pos < rseq.len() {
                    inputs.push((*rs, rseq[pos]));
                }
            } else if t == lens.iter().copied().min().unwrap() {
                let victim = lens
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &l)| l)
                    .map(|(i, _)| i)
                    .unwrap();
                be.release(slots[victim]);
                let fresh_slot = be.try_admit().unwrap();
                assert_eq!(fresh_slot, slots[victim], "freed slot is reused");
                let rseq: Vec<i32> =
                    (0..3).map(|i| ((i * 11 + 2) % cfg.vocab) as i32).collect();
                let fresh_engine = DecodeEngine::on_chip(
                    DecodeModel::synth(cfg.clone(), seed),
                    params.clone(),
                    strategy,
                );
                inputs.push((fresh_slot, rseq[0]));
                replacement = Some((fresh_slot, rseq, fresh_engine));
            }
            if inputs.is_empty() {
                break;
            }
            be.step(&inputs);
            // verify every stepped lane against its single-stream twin
            for (i, seq) in seqs.iter().enumerate() {
                if t < seq.len() && replacement.as_ref().map(|(rs, _, _)| *rs) != Some(slots[i])
                {
                    let want = singles[i].forward(seq[t]).to_vec();
                    assert_eq!(
                        be.logits(slots[i]),
                        want.as_slice(),
                        "{strategy:?} slot {i} pos {t}"
                    );
                }
            }
            if let Some((rs, rseq, eng)) = &mut replacement {
                let min_len = lens.iter().copied().min().unwrap();
                if t >= min_len {
                    let pos = t - min_len;
                    if pos < rseq.len() {
                        let want = eng.forward(rseq[pos]).to_vec();
                        assert_eq!(
                            be.logits(*rs),
                            want.as_slice(),
                            "{strategy:?} replacement pos {pos}"
                        );
                    }
                }
            }
        }
    });
}
