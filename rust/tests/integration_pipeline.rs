//! Integration: the full framework pipeline (Fig. 2a) across all paper
//! models and strategies, with cross-module consistency checks.

use monarch_cim::cim::CimParams;
use monarch_cim::coordinator::{run_pipeline, PipelineConfig};
use monarch_cim::mapping::{map_model, Strategy};
use monarch_cim::model::{count_report, ModelConfig};
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::util::stats::geomean;

#[test]
fn pipeline_all_models_all_strategies() {
    for model in ModelConfig::paper_models() {
        for strategy in Strategy::all() {
            let r = run_pipeline(&PipelineConfig::new(model.clone(), strategy));
            assert!(r.mapping.arrays > 0, "{}/{:?}", model.name, strategy);
            assert!(r.cost.latency_ms() > 0.0);
            assert!(r.cost.energy_mj() > 0.0);
            assert!(r.mapping.utilization() > 0.0 && r.mapping.utilization() <= 1.0 + 1e-9);
        }
    }
}

#[test]
fn paper_headline_claims_hold() {
    // Abstract: ">50% utilization improvement, >4x memory footprint and
    // FLOPs reduction, >1.7x latency/energy vs dense CIM baseline".
    let params = CimParams::default();

    // utilization improvement DenseMap vs SparseMap
    let cfg = ModelConfig::bert_large();
    let sp = map_model(&cfg, &params, Strategy::SparseMap);
    let de = map_model(&cfg, &params, Strategy::DenseMap);
    assert!(de.utilization() - sp.utilization() > 0.5);

    // >4x memory footprint reduction (weights stored)
    let lin = map_model(&cfg, &params, Strategy::Linear);
    assert!(lin.used_cells() as f64 / de.used_cells() as f64 > 4.0);

    // >4x FLOPs reduction on parameterized matmuls
    let counts = count_report(&cfg);
    assert!(
        counts.dense_para_flops as f64 / counts.monarch_para_flops as f64 > 4.0
    );

    // >1.7x latency and energy reduction (geomean, DenseMap)
    let mut lat = Vec::new();
    let mut en = Vec::new();
    for m in ModelConfig::paper_models() {
        let l = cost_report(&m, &params, Strategy::Linear);
        let d = cost_report(&m, &params, Strategy::DenseMap);
        lat.push(l.latency_ms() / d.latency_ms());
        en.push(l.energy_mj() / d.energy_mj());
    }
    assert!(geomean(&lat) > 1.6, "latency geomean {}", geomean(&lat));
    assert!(geomean(&en) > 1.6, "energy geomean {}", geomean(&en));
}

#[test]
fn mapping_ops_cover_all_para_matmuls() {
    for model in ModelConfig::paper_models() {
        let para = monarch_cim::model::para_ops(&model);
        for strategy in Strategy::all() {
            let mm = map_model(&model, &CimParams::default(), strategy);
            assert_eq!(
                mm.ops.len(),
                para.len(),
                "{}/{:?}: op count",
                model.name,
                strategy
            );
            // every op must have at least one placement
            for (i, op) in mm.ops.iter().enumerate() {
                assert!(
                    !op.arrays.is_empty(),
                    "{}/{:?}: op {i} ({}) has no arrays",
                    model.name,
                    strategy,
                    op.name
                );
            }
        }
    }
}

#[test]
fn placements_within_array_bounds() {
    for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
        let mm = map_model(
            &ModelConfig::bart_large(),
            &CimParams::default(),
            strategy,
        );
        let lanes = mm.m / mm.b;
        for p in &mm.placements {
            assert!(p.array < mm.arrays);
            assert!(p.diag < lanes, "diag {} >= lanes {lanes}", p.diag);
            assert!(p.blocks <= lanes);
            assert!(p.cells <= mm.m * mm.m);
        }
    }
}

#[test]
fn dse_pipeline_monotone_in_adcs_for_column_muxed() {
    // more ADCs per array can only help Linear and SparseMap
    let cfg = ModelConfig::gpt2_medium();
    for strategy in [Strategy::Linear, Strategy::SparseMap] {
        let mut prev = f64::INFINITY;
        for adcs in [1usize, 2, 4, 8, 16, 32] {
            let p = CimParams::default().with_adcs_per_array(adcs);
            let r = cost_report(&cfg, &p, strategy);
            assert!(
                r.latency_ms() <= prev + 1e-12,
                "{strategy:?}: latency not monotone at {adcs} ADCs"
            );
            prev = r.latency_ms();
        }
    }
}
