//! Property tests for the compiled execution plans (`scheduler::plan`):
//!
//! * Replaying the compiled plan is **bit-identical** to a freshly
//!   recomputed `placement_schedule` execution, for random transformer
//!   geometries under all three mapping strategies.
//! * The plan's driven rows / converted columns exactly match the
//!   scheduler's auditable per-token command stream (`token_commands`) —
//!   the plan is a resolved view of the same schedule, never a different
//!   one.
//! * Pass tables respect array bounds and the §III-C DenseMap walk
//!   granularity.
//! * The bit-block pass encoding (u64 words + popcnt dense indexing,
//!   DESIGN.md §6e) replays bit-identically to the index-list encoding
//!   and the recompute audit path, including at array dims straddling
//!   the word boundary (63/64/65) and on fully-dense words.

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::{map_ops, Strategy};
use monarch_cim::monarch::RectMonarch;
use monarch_cim::scheduler::{compile_plan, token_commands, CimCommand};
use monarch_cim::sim::exec::{FunctionalChip, ReplayMode};
use monarch_cim::util::prop::forall;
use monarch_cim::util::rng::Pcg32;

mod common;
use common::{random_model_ops, rect_randn};

#[test]
fn prop_compiled_replay_bit_identical_to_recompute() {
    forall("plan replay == schedule recompute (bitwise)", 10, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(common::seed(g));
        let weights: Vec<RectMonarch> = ops
            .iter()
            .map(|op| rect_randn(op.rows, op.cols, d, &mut rng))
            .collect();
        for strategy in Strategy::all() {
            let mut chip =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for oi in 0..ops.len() {
                let x = rng.normal_vec(ops[oi].cols);
                let planned = chip.run_op(oi, &x);
                let recomputed = chip.run_op_recompute(oi, &x);
                assert_eq!(
                    planned, recomputed,
                    "{strategy:?} op {oi}: compiled replay diverged from \
                     freshly recomputed schedules"
                );
                if strategy != Strategy::Linear {
                    // Monarch replay also reproduces the factored
                    // reference bit for bit (same f32 ops, same order).
                    assert_eq!(
                        planned,
                        weights[oi].matvec(&x),
                        "{strategy:?} op {oi}: replay vs reference"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_batched_replay_bit_identical_to_recompute() {
    // The audit-reference contract extended to the batched path: every
    // lane of run_op_batch_into equals the freshly recomputed schedule
    // execution (and the single-stream replay) bit for bit, and B=1
    // takes the single-stream fast path exactly.
    forall("batched replay == schedule recompute per lane", 8, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(common::seed(g));
        let weights: Vec<RectMonarch> = ops
            .iter()
            .map(|op| rect_randn(op.rows, op.cols, d, &mut rng))
            .collect();
        let batch = g.usize(2, 8);
        for strategy in Strategy::all() {
            let mut chip =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for oi in 0..ops.len() {
                let lanes: Vec<Vec<f32>> =
                    (0..batch).map(|_| rng.normal_vec(ops[oi].cols)).collect();
                let mut xs = vec![0.0f32; ops[oi].cols * batch];
                for (l, x) in lanes.iter().enumerate() {
                    for (c, &v) in x.iter().enumerate() {
                        xs[c * batch + l] = v;
                    }
                }
                let ys = chip.run_op_batch(oi, batch, &xs);
                // one lane per op through the (slow) schedule-recompute
                // audit path; every lane through the single-stream replay
                // (itself recompute-verified above) — keeps the test fast
                // without weakening the audit chain
                let audit_lane = g.usize(0, batch - 1);
                for (l, x) in lanes.iter().enumerate() {
                    let want = if l == audit_lane {
                        chip.run_op_recompute(oi, x)
                    } else {
                        chip.run_op(oi, x)
                    };
                    for r in 0..ops[oi].rows {
                        assert_eq!(
                            ys[r * batch + l].to_bits(),
                            want[r].to_bits(),
                            "{strategy:?} op {oi} lane {l} row {r}: batched lane \
                             diverged from the single-stream path"
                        );
                    }
                }
                // B=1 fast-path equivalence: identical to run_op_into
                let x = &lanes[0];
                assert_eq!(
                    chip.run_op_batch(oi, 1, x),
                    chip.run_op(oi, x),
                    "{strategy:?} op {oi}: B=1 fast path"
                );
            }
        }
    });
}

#[test]
fn prop_bitblock_replay_bit_identical_at_word_boundaries() {
    // The tentpole safety net: bit-block replay (the default encoding)
    // must match index-list replay AND the schedule-recompute audit
    // path bitwise, across random geometries and every strategy —
    // explicitly sampling array dims straddling the u64 word boundary
    // (63, 64, 65) and dims where whole passes are fully-dense words
    // (m = 32/64: Linear drives all m rows, degenerating the bit set to
    // the identity prefix).
    forall("bit-block replay == index replay == recompute", 8, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[32usize, 63, 64, 65]);
        if b > m {
            return;
        }
        let (cfg, ops) = random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(common::seed(g));
        let weights: Vec<RectMonarch> = ops
            .iter()
            .map(|op| rect_randn(op.rows, op.cols, d, &mut rng))
            .collect();
        let batch = g.usize(2, 5);
        for strategy in Strategy::all() {
            let mut chip =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for oi in 0..ops.len() {
                let x = rng.normal_vec(ops[oi].cols);
                chip.set_replay_mode(ReplayMode::BitBlock);
                let bits = chip.run_op(oi, &x);
                chip.set_replay_mode(ReplayMode::IndexList);
                let idx = chip.run_op(oi, &x);
                let audit = chip.run_op_recompute(oi, &x);
                for r in 0..ops[oi].rows {
                    assert_eq!(
                        bits[r].to_bits(),
                        idx[r].to_bits(),
                        "{strategy:?} m={m} op {oi} row {r}: bit-block vs index replay"
                    );
                    assert_eq!(
                        bits[r].to_bits(),
                        audit[r].to_bits(),
                        "{strategy:?} m={m} op {oi} row {r}: bit-block vs recompute"
                    );
                }
                // batched path, both encodings, stride-B lanes
                let lanes: Vec<Vec<f32>> =
                    (0..batch).map(|_| rng.normal_vec(ops[oi].cols)).collect();
                let mut xs = vec![0.0f32; ops[oi].cols * batch];
                for (l, lx) in lanes.iter().enumerate() {
                    for (c, &v) in lx.iter().enumerate() {
                        xs[c * batch + l] = v;
                    }
                }
                chip.set_replay_mode(ReplayMode::BitBlock);
                let yb = chip.run_op_batch(oi, batch, &xs);
                chip.set_replay_mode(ReplayMode::IndexList);
                let yi = chip.run_op_batch(oi, batch, &xs);
                for (k, (gb, gi)) in yb.iter().zip(&yi).enumerate() {
                    assert_eq!(
                        gb.to_bits(),
                        gi.to_bits(),
                        "{strategy:?} m={m} op {oi} batch {batch} slot {k}: \
                         batched bit-block vs index replay"
                    );
                }
                chip.set_replay_mode(ReplayMode::BitBlock);
            }
        }
    });
}

#[test]
fn prop_plan_matches_token_commands() {
    forall("plan rows/cols == token_commands", 10, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        for strategy in Strategy::all() {
            let mm = map_ops(&cfg, &ops, &params, strategy);
            let plan = compile_plan(&mm);
            // The stream pairs every DriveRows with the Convert that
            // follows it; collect those (array, rows, cols) triples.
            let mut cmd_passes: Vec<(usize, Vec<usize>, Vec<usize>)> = Vec::new();
            let mut pending: Option<(usize, Vec<usize>)> = None;
            for cmd in token_commands(&mm, &params) {
                match cmd {
                    CimCommand::DriveRows { array, rows } => {
                        assert!(pending.is_none(), "{strategy:?}: unpaired drive");
                        pending = Some((array, rows));
                    }
                    CimCommand::Convert { array, cols, .. } => {
                        let (a, rows) = pending.take().expect("convert without drive");
                        assert_eq!(a, array, "{strategy:?}: drive/convert array");
                        cmd_passes.push((array, rows, cols));
                    }
                    _ => {}
                }
            }
            assert!(pending.is_none());
            let plan_passes: Vec<(usize, Vec<usize>, Vec<usize>)> = plan
                .ops
                .iter()
                .flat_map(|o| o.passes.iter())
                .map(|p| (p.array, p.rows.clone(), p.cols.clone()))
                .collect();
            assert_eq!(
                plan_passes.len(),
                cmd_passes.len(),
                "{strategy:?}: pass count"
            );
            if strategy == Strategy::Linear {
                // One placement (and one pass) per array: pair by array.
                // The stream converts all m columns; the plan keeps the
                // truncated prefix that lands in the output tile.
                for (array, rows, cols) in &plan_passes {
                    let cmd = cmd_passes
                        .iter()
                        .find(|(a, _, _)| a == array)
                        .unwrap_or_else(|| panic!("no commands for array {array}"));
                    assert_eq!(rows, &cmd.1, "Linear rows, array {array}");
                    assert_eq!(
                        cols.as_slice(),
                        &cmd.2[..cols.len()],
                        "Linear cols prefix, array {array}"
                    );
                }
            } else {
                // Multiset equality: the plan is exactly the command
                // stream's drive/convert work, reordered per-op.
                let mut a = plan_passes;
                let mut c = cmd_passes;
                a.sort();
                c.sort();
                assert_eq!(a, c, "{strategy:?}: plan != command stream");
            }
        }
    });
}

#[test]
fn prop_plan_passes_respect_geometry() {
    forall("plan pass geometry", 10, |g| {
        let d = g.choose(&[16usize, 64]);
        let b = (d as f64).sqrt() as usize;
        let m = g.choose(&[16usize, 32, 64]);
        if b > m {
            return;
        }
        let (cfg, ops) = random_model_ops(g, d);
        let mut params = CimParams::default();
        params.array_dim = m;
        for strategy in Strategy::all() {
            let mm = map_ops(&cfg, &ops, &params, strategy);
            let plan = compile_plan(&mm);
            assert_eq!(plan.ops.len(), mm.ops.len());
            assert_eq!(plan.m, mm.m);
            for (oi, oplan) in plan.ops.iter().enumerate() {
                assert!(!oplan.passes.is_empty(), "{strategy:?} op {oi}: no passes");
                for pass in &oplan.passes {
                    assert!(pass.array < mm.arrays);
                    assert!(pass.n_in <= pass.rows.len());
                    assert!(pass.rows.iter().all(|&r| r < mm.m), "{strategy:?} rows");
                    assert!(pass.cols.iter().all(|&c| c < mm.m), "{strategy:?} cols");
                    if strategy == Strategy::DenseMap {
                        // §III-C walk: block-granular passes
                        assert_eq!(pass.rows.len(), mm.b);
                        assert_eq!(pass.cols.len(), mm.b);
                    }
                }
            }
        }
    });
}
