//! Property tests for end-to-end request tracing (DESIGN.md §6h): over
//! random model geometries, mapping strategies, worker counts, shard
//! counts, speculation and prefix-cache settings,
//!
//! 1. a traced serving run is **bit-identical** to an untraced one —
//!    tracing only observes the engine, it never touches its state; and
//! 2. the recorded span tree is **well-formed**: every request has one
//!    enqueue and one admit, every admit has exactly one reply, chunk
//!    spans nest inside [admit, reply] on the worker that admitted the
//!    request, chunk position counters tile the window contiguously
//!    from the spliced prefix, and the chunk events' modeled chip time
//!    sums to the reply's per-request total (the same numbers
//!    `Metrics::record_sim_tokens` bills).

use std::collections::BTreeMap;
use std::sync::Arc;

use monarch_cim::coordinator::tracing::{Event, EventKind, Tracer};
use monarch_cim::coordinator::{Backend, CimSimConfig, InferenceServer, ServerConfig};
use monarch_cim::util::prop::forall;

mod common;

/// Serve `windows` in submission order on a fresh server and return the
/// per-request logits. The tracer (when given) is threaded through the
/// backend config exactly like `monarch-cim serve --trace-out` does.
fn serve_windows(
    sim: &CimSimConfig,
    windows: &[Vec<i32>],
    trace: Option<Arc<Tracer>>,
) -> Vec<Vec<f32>> {
    let mut sim = sim.clone();
    sim.trace = trace;
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(sim),
        ..Default::default()
    })
    .expect("server starts");
    let pending: Vec<_> = windows
        .iter()
        .map(|w| server.submit(w.clone()).expect("submit"))
        .collect();
    let out: Vec<Vec<f32>> = pending
        .into_iter()
        .map(|p| p.wait().expect("reply"))
        .collect();
    server.shutdown();
    out
}

#[test]
fn prop_traced_run_bit_identical_and_spans_well_formed() {
    forall("traced == untraced + well-formed spans", 4, |g| {
        let model = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&model, &params) {
            return;
        }
        let sim = CimSimConfig {
            strategy: common::any_strategy(g),
            cim: params,
            seed: common::seed(g),
            prefill_chunk: g.usize(0, 4),
            speculate_k: g.choose(&[0usize, 2]),
            draft_layers: 0,
            shards: g.usize(1, 2),
            workers: g.usize(1, 2),
            prefix_cache: g.choose(&[0usize, 4]),
            trace: None,
            model: model.clone(),
        };
        // a few ragged windows, some sharing a prefix so the splice and
        // hit-rate trace paths run
        let n_req = g.usize(3, 6);
        let prefix_len = g.usize(1, model.seq / 2);
        let prefix: Vec<i32> = (0..prefix_len)
            .map(|i| ((i * 13 + 5) % model.vocab) as i32)
            .collect();
        let windows: Vec<Vec<i32>> = (0..n_req)
            .map(|r| {
                let mut w: Vec<i32> = if g.bool() { prefix.clone() } else { Vec::new() };
                let tail = g.usize(1, model.seq - w.len());
                w.extend((0..tail).map(|i| ((i * 29 + r * 7 + 3) % model.vocab) as i32));
                w
            })
            .collect();

        let untraced = serve_windows(&sim, &windows, None);
        let tracer = Arc::new(Tracer::new(16384));
        let traced = serve_windows(&sim, &windows, Some(tracer.clone()));

        // (1) tracing never perturbs what the chip computes
        for (i, (a, b)) in untraced.iter().zip(&traced).enumerate() {
            assert_eq!(
                a, b,
                "request {i}: traced logits drifted from the untraced run"
            );
        }

        // (2) span-tree well-formedness over the merged event list
        let events = tracer.events();
        assert_eq!(tracer.dropped(), 0, "ring overflowed in a small run");
        let mut enqueue: BTreeMap<u64, Event> = BTreeMap::new();
        let mut admit: BTreeMap<u64, Event> = BTreeMap::new();
        let mut splice: BTreeMap<u64, Event> = BTreeMap::new();
        let mut end: BTreeMap<u64, Event> = BTreeMap::new();
        let mut chunks: BTreeMap<u64, Vec<Event>> = BTreeMap::new();
        for ev in &events {
            match ev.kind {
                EventKind::Enqueue => {
                    assert!(
                        enqueue.insert(ev.request, *ev).is_none(),
                        "request {} enqueued twice",
                        ev.request
                    );
                }
                EventKind::Admit => {
                    assert!(
                        admit.insert(ev.request, *ev).is_none(),
                        "request {} admitted twice",
                        ev.request
                    );
                }
                EventKind::PrefixSplice => {
                    assert!(
                        splice.insert(ev.request, *ev).is_none(),
                        "request {} spliced twice",
                        ev.request
                    );
                }
                EventKind::Reply | EventKind::Cancel => {
                    assert!(
                        end.insert(ev.request, *ev).is_none(),
                        "request {} ended twice",
                        ev.request
                    );
                }
                EventKind::PrefillChunk | EventKind::DecodeStep | EventKind::SpecRound => {
                    chunks.entry(ev.request).or_default().push(*ev);
                }
                _ => {}
            }
        }
        for (i, w) in windows.iter().enumerate() {
            // ids are handed out in submission order, starting at 1
            let id = i as u64 + 1;
            let nq = enqueue.get(&id).expect("every request has an enqueue");
            assert_eq!(nq.a as usize, w.len(), "enqueue carries the prompt length");
            let a = admit.get(&id).expect("every request is admitted");
            assert!(
                a.t_start_us <= a.t_end_us,
                "request {id}: queue-wait span runs backwards"
            );
            assert_eq!(a.b as usize, w.len(), "admit carries the window length");
            let e = end.get(&id).expect("every admitted request ends");
            assert_eq!(
                e.kind,
                EventKind::Reply,
                "request {id}: all clients waited, so every end is a reply"
            );
            assert_eq!(e.b as usize, w.len());
            let spliced = splice.get(&id).map(|s| s.a as usize).unwrap_or(0);
            assert_eq!(
                e.a as usize,
                w.len() - spliced,
                "request {id}: reply counts the positions replayed on-chip"
            );
            // chunk spans: same worker, nested in [admit, reply], tiling
            // the window contiguously from the spliced prefix
            let mut cs = chunks.remove(&id).expect("every request stepped");
            cs.sort_by_key(|c| c.b);
            let mut fed = spliced;
            let mut chunk_sim_ns = 0.0f64;
            for c in &cs {
                assert_eq!(
                    c.worker, a.worker,
                    "request {id}: chunk stepped on a different worker than admitted"
                );
                assert!(
                    c.t_start_us >= a.t_end_us && c.t_end_us <= e.t_end_us,
                    "request {id}: chunk span escapes [admit, reply]"
                );
                assert_eq!(
                    c.b as usize, fed,
                    "request {id}: chunk does not continue where the last ended"
                );
                fed += c.a as usize;
                chunk_sim_ns += c.sim_ns;
            }
            assert_eq!(fed, w.len(), "request {id}: chunks do not tile the window");
            // the chunk events' modeled deltas partition the request's
            // trace, so they sum to the reply's total (float association
            // order is the only slack)
            let tol = 1e-6 * e.sim_ns.max(1.0);
            assert!(
                (chunk_sim_ns - e.sim_ns).abs() <= tol,
                "request {id}: chunk sim_ns {} != reply total {}",
                chunk_sim_ns,
                e.sim_ns
            );
        }
        assert!(
            chunks.is_empty(),
            "chunk events recorded for unknown requests: {:?}",
            chunks.keys().collect::<Vec<_>>()
        );
    });
}
