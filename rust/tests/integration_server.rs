//! Integration: the batching inference server — concurrency, continuous
//! batching behaviour, output fidelity, error paths and clean shutdown.
//!
//! The behavioural tests run on [`Backend::CimSim`] (the emulated
//! crossbar decode engine behind the continuous-batching slot loop),
//! which needs no AOT artifacts and therefore runs everywhere; the
//! PJRT-specific startup contract is covered at the end. PJRT kernel
//! fidelity itself lives in `integration_runtime.rs`.

use monarch_cim::coordinator::batching::BatchPolicy;
use monarch_cim::coordinator::{Backend, CimSimConfig, InferenceServer, ServerConfig};
use monarch_cim::mapping::Strategy;
use monarch_cim::sim::decode::{DecodeEngine, DecodeModel};
use monarch_cim::util::rng::Pcg32;

fn start_server() -> InferenceServer {
    InferenceServer::start(ServerConfig::cim_sim(Strategy::DenseMap))
        .expect("CIM-sim server start")
}

#[test]
fn serves_concurrent_requests() {
    let server = start_server();
    let seq = server.seq;
    let vocab = server.vocab as u32;
    std::thread::scope(|scope| {
        for i in 0..24u64 {
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Pcg32::new(i);
                let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
                let logits = srv.infer(toks).expect("inference");
                assert_eq!(logits.len(), seq * srv.vocab);
                assert!(logits.iter().all(|v| v.is_finite()));
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 24);
    assert!(snap.batches <= 24);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.sim_tokens, 24 * seq as u64);
    server.shutdown();
}

#[test]
fn continuous_batching_overlaps_requests() {
    // 16 concurrent full-window requests through 8 slots: the slot loop
    // must actually overlap sequences (mean per-step occupancy > 1)
    // instead of serving them one after another.
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig::default()),
        policy: BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(30),
        },
        ..Default::default()
    })
    .expect("server start");
    let seq = server.seq;
    std::thread::scope(|scope| {
        for _ in 0..16 {
            let srv = &server;
            scope.spawn(move || {
                let toks = vec![1i32; seq];
                srv.infer(toks).expect("inference");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 16);
    assert_eq!(snap.slot_capacity, 8);
    assert!(
        snap.occupancy_mean > 1.0,
        "expected overlapped sequences, got mean occupancy {}",
        snap.occupancy_mean
    );
    assert!(snap.occupancy_peak >= 2, "peak {}", snap.occupancy_peak);
    assert!(snap.occupancy_peak <= 8, "peak exceeds capacity");
    assert!(snap.sim_tokens_per_sec > 0.0);
    server.shutdown();
}

#[test]
fn concurrent_ragged_clients_match_reference_engine() {
    // The ISSUE-3 serving contract: N threads submit windows of
    // DIFFERENT lengths; continuous batching interleaves them at
    // ragged positions, yet every client gets logits identical to a
    // single-stream reference engine scoring its window alone (the
    // DenseMap chip replay is bit-identical to the factored reference),
    // and the occupancy metric is exercised.
    let server = start_server();
    let seq = server.seq;
    let vocab = server.vocab;
    // windows of mixed lengths, long enough that admissions overlap
    let windows: Vec<Vec<i32>> = (0..12u64)
        .map(|i| {
            let mut rng = Pcg32::new(4000 + i);
            let len = 8 + (i as usize * 7) % (seq - 8);
            (0..len).map(|_| rng.below(vocab as u32) as i32).collect()
        })
        .collect();
    // golden logits from one single-stream reference engine (same
    // synthesis seed as CimSimConfig::default)
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    let expected: Vec<Vec<f32>> = windows.iter().map(|w| golden.score(w).0).collect();
    std::thread::scope(|scope| {
        for (w, want) in windows.iter().zip(&expected) {
            let srv = &server;
            scope.spawn(move || {
                let got = srv.infer(w.clone()).expect("inference");
                assert_eq!(got.len(), w.len() * srv.vocab);
                assert_eq!(&got, want, "ragged batchmates changed the logits");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 12);
    assert_eq!(snap.errors, 0);
    let tokens: usize = windows.iter().map(|w| w.len()).sum();
    assert_eq!(snap.sim_tokens, tokens as u64);
    assert!(snap.occupancy_mean >= 1.0, "occupancy not recorded");
    assert!(snap.occupancy_peak >= 1);
    server.shutdown();
}

#[test]
fn chunked_prefill_server_matches_reference_and_reports_phases() {
    // ISSUE-4 serving contract: a server ingesting prompts 4 positions
    // per replay must return logits identical to the single-stream
    // reference, count prefill chunks, and report TTFT separately from
    // the inter-token decode cadence.
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            prefill_chunk: 4,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: std::time::Duration::from_millis(10),
        },
        ..Default::default()
    })
    .expect("server start");
    let seq = server.seq;
    let vocab = server.vocab;
    let windows: Vec<Vec<i32>> = (0..6u64)
        .map(|i| {
            let mut rng = Pcg32::new(7000 + i);
            let len = 6 + (i as usize * 5) % (seq - 6);
            (0..len).map(|_| rng.below(vocab as u32) as i32).collect()
        })
        .collect();
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    let expected: Vec<Vec<f32>> = windows.iter().map(|w| golden.score(w).0).collect();
    std::thread::scope(|scope| {
        for (w, want) in windows.iter().zip(&expected) {
            let srv = &server;
            scope.spawn(move || {
                let got = srv.infer(w.clone()).expect("inference");
                assert_eq!(&got, want, "chunked ingestion changed the logits");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 6);
    assert_eq!(snap.errors, 0);
    let tokens: usize = windows.iter().map(|w| w.len()).sum();
    assert_eq!(snap.sim_tokens, tokens as u64);
    assert!(
        snap.prefill_chunks > 0,
        "no multi-position replays recorded despite prefill_chunk=4"
    );
    assert!(snap.prefill_positions >= 2 * snap.prefill_chunks);
    assert!(snap.ttft_p50_us > 0.0, "TTFT not recorded");
    assert!(
        snap.inter_token_p50_us > 0.0,
        "inter-token latency not recorded (windows span several chunks)"
    );
    // TTFT covers at most the first chunk; a full window takes several
    // steps more, so the blended p50 latency must sit above TTFT's share
    assert!(snap.latency_p50_us >= snap.ttft_p50_us);
    server.shutdown();
}

#[test]
fn speculative_server_matches_reference_and_records_acceptance() {
    // ISSUE-5 serving contract: with speculate_k > 0 a self-draft races
    // ahead of every window and verify chunks span the agreed run —
    // scores must stay bit-identical to the single-stream reference,
    // and the acceptance counters must move. Windows are built as
    // greedy continuations of the target model, so the full-depth
    // self-draft provably agrees in the generated region
    // (acceptance > 0 is deterministic, not luck).
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            speculate_k: 4,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: std::time::Duration::from_millis(10),
        },
        ..Default::default()
    })
    .expect("server start");
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    // half target-greedy windows (draft agrees), half random (draft
    // mostly disagrees — the correction path)
    let mut windows: Vec<Vec<i32>> = Vec::new();
    for i in 0..3u64 {
        let prompt: Vec<i32> = (0..3).map(|j| ((i * 31 + j * 7 + 1) % 256) as i32).collect();
        let gen = golden.generate(&prompt, 8);
        let mut w = prompt;
        w.extend_from_slice(&gen.tokens);
        windows.push(w);
    }
    for i in 0..3u64 {
        let mut rng = Pcg32::new(9000 + i);
        windows.push((0..11).map(|_| rng.below(server.vocab as u32) as i32).collect());
    }
    let expected: Vec<Vec<f32>> = windows.iter().map(|w| golden.score(w).0).collect();
    std::thread::scope(|scope| {
        for (w, want) in windows.iter().zip(&expected) {
            let srv = &server;
            scope.spawn(move || {
                let got = srv.infer(w.clone()).expect("inference");
                assert_eq!(&got, want, "speculative chunking changed the logits");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 6);
    assert_eq!(snap.errors, 0);
    assert!(snap.spec_rounds > 0, "no speculative rounds recorded");
    assert!(
        snap.spec_acceptance_rate > 0.0,
        "greedy-continuation windows must yield accepted proposals"
    );
    assert!(
        snap.spec_tokens_per_round >= 1.0,
        "a verify round always advances at least one position"
    );
    server.shutdown();
}

#[test]
fn speculate_zero_is_byte_identical_to_plain_serving() {
    // the knob's off position IS the PR-4 path: same windows through a
    // speculate_k=0 server and a speculative one must produce
    // byte-identical logits, and the k=0 server must record no rounds
    let mk = |k: usize| {
        InferenceServer::start(ServerConfig {
            backend: Backend::CimSim(CimSimConfig {
                speculate_k: k,
                ..Default::default()
            }),
            policy: BatchPolicy {
                max_batch: 2,
                max_delay: std::time::Duration::from_millis(5),
            },
            ..Default::default()
        })
        .expect("server start")
    };
    let plain = mk(0);
    let spec = mk(4);
    let mut rng = Pcg32::new(321);
    for len in [1usize, 5, 12, plain.seq] {
        let toks: Vec<i32> = (0..len)
            .map(|_| rng.below(plain.vocab as u32) as i32)
            .collect();
        let a = plain.infer(toks.clone()).expect("plain inference");
        let b = spec.infer(toks).expect("speculative inference");
        assert_eq!(a, b, "len {len}: speculation changed the scores");
    }
    let snap = plain.metrics.snapshot();
    assert_eq!(snap.spec_rounds, 0, "k=0 must never speculate");
    assert_eq!(snap.spec_acceptance_rate, 0.0);
    let snap = spec.metrics.snapshot();
    assert!(snap.spec_rounds > 0, "k=4 server never speculated");
    plain.shutdown();
    spec.shutdown();
}

#[test]
fn server_output_is_deterministic() {
    // The same window must produce identical logits on repeat requests
    // and across separately started servers (seeded weight synthesis).
    let server = start_server();
    let seq = server.seq;
    let mut rng = Pcg32::new(17);
    let toks: Vec<i32> = (0..seq)
        .map(|_| rng.below(server.vocab as u32) as i32)
        .collect();
    let a = server.infer(toks.clone()).unwrap();
    let b = server.infer(toks.clone()).unwrap();
    assert_eq!(a, b, "repeat request changed the logits");
    server.shutdown();
    let server2 = start_server();
    let c = server2.infer(toks).unwrap();
    assert_eq!(a, c, "fresh server produced different logits");
    server2.shutdown();
}

#[test]
fn batch_identity_independent_of_batchmates() {
    // The same request must produce the same logits whether it is alone
    // in a batch or grouped with others.
    let server = start_server();
    let seq = server.seq;
    let mut rng = Pcg32::new(99);
    let toks: Vec<i32> = (0..seq)
        .map(|_| rng.below(server.vocab as u32) as i32)
        .collect();
    let solo = server.infer(toks.clone()).unwrap();
    // now issue it together with 7 concurrent others
    let mut grouped = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..8u64 {
            let srv = &server;
            let t = if i == 0 {
                toks.clone()
            } else {
                let mut r = Pcg32::new(1000 + i);
                (0..seq).map(|_| r.below(srv.vocab as u32) as i32).collect()
            };
            handles.push(scope.spawn(move || srv.infer(t).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.join().unwrap();
            if i == 0 {
                grouped = r;
            }
        }
    });
    assert_eq!(solo, grouped, "batchmates contaminated the result");
    server.shutdown();
}

#[test]
fn invalid_requests_get_errors_not_hangs() {
    let server = start_server();
    let seq = server.seq;
    // empty window
    let err = server.infer(Vec::new()).unwrap_err();
    assert!(err.to_string().contains("invalid request"), "{err}");
    // window longer than the context
    let err = server.infer(vec![0i32; seq + 1]).unwrap_err();
    assert!(err.to_string().contains("invalid request"), "{err}");
    // out-of-vocab token
    let mut toks = vec![0i32; seq];
    toks[0] = 1_000_000;
    assert!(server.infer(toks).is_err());
    // ragged-but-valid short window IS servable now
    let short = server.infer(vec![1i32; 3]).expect("short window");
    assert_eq!(short.len(), 3 * server.vocab);
    // server still healthy afterwards
    let ok = server.infer(vec![1i32; seq]);
    assert!(ok.is_ok());
    let snap = server.metrics.snapshot();
    assert_eq!(snap.errors, 3);
    server.shutdown();
}

#[test]
fn sim_metrics_track_modeled_chip_cost() {
    let server = start_server();
    let seq = server.seq;
    for _ in 0..3 {
        server.infer(vec![2i32; seq]).unwrap();
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.sim_tokens, 3 * seq as u64);
    assert!(snap.sim_token_latency_ns > 0.0, "no modeled latency");
    assert!(snap.sim_energy_nj > 0.0, "no modeled energy");
    server.shutdown();
}

#[test]
fn strategies_serve_interchangeably() {
    // All three mapping strategies must serve the same token window with
    // matching greedy structure (Linear only to float tolerance).
    let mut outputs = Vec::new();
    for strategy in Strategy::all() {
        let server = InferenceServer::start(ServerConfig::cim_sim(strategy))
            .expect("server start");
        let toks: Vec<i32> = (0..server.seq).map(|i| (i % 17) as i32).collect();
        outputs.push(server.infer(toks).unwrap());
        server.shutdown();
    }
    // SparseMap vs DenseMap: bit-identical
    assert_eq!(outputs[1], outputs[2], "sparse vs dense logits differ");
    // Linear vs factored: float tolerance
    let max_diff = outputs[0]
        .iter()
        .zip(&outputs[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "linear strayed: {max_diff}");
}

#[test]
fn multi_worker_ragged_clients_match_reference_engine() {
    // ISSUE-8 serving contract: W independent worker chips pull from
    // one shared queue, and every concurrent ragged client still gets
    // logits bit-identical to a single-stream reference engine scoring
    // its window alone — identical weights from the shared synthesis
    // seed mean any worker serves any request identically, and the
    // dispatcher must never mix up replies.
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            workers: 3,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 2,
            max_delay: std::time::Duration::from_millis(10),
        },
        ..Default::default()
    })
    .expect("server start");
    let seq = server.seq;
    let vocab = server.vocab;
    let windows: Vec<Vec<i32>> = (0..18u64)
        .map(|i| {
            let mut rng = Pcg32::new(5000 + i);
            let len = 4 + (i as usize * 5) % (seq - 4);
            (0..len).map(|_| rng.below(vocab as u32) as i32).collect()
        })
        .collect();
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    let expected: Vec<Vec<f32>> = windows.iter().map(|w| golden.score(w).0).collect();
    std::thread::scope(|scope| {
        for (w, want) in windows.iter().zip(&expected) {
            let srv = &server;
            scope.spawn(move || {
                let got = srv.infer(w.clone()).expect("inference");
                assert_eq!(&got, want, "multi-worker serving changed the logits");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 18);
    assert_eq!(snap.errors, 0);
    let tokens: usize = windows.iter().map(|w| w.len()).sum();
    assert_eq!(snap.sim_tokens, tokens as u64);
    // load actually spread: with 18 clients blocked on a 2-slot-per-
    // worker pool, the idle workers must have pulled queued work (a
    // worker appears here once it stepped at least once)
    assert!(
        snap.workers >= 2,
        "queue never dispatched beyond one worker (reported {})",
        snap.workers
    );
    assert_eq!(snap.worker_occupancy.len(), snap.workers);
    server.shutdown();
}

#[test]
fn shared_prefix_cache_skips_prefill_bit_identically() {
    // ISSUE-8 tentpole contract on the serving path: windows opening
    // with a cached prefix splice donor KV instead of prefilling, the
    // logits stay bitwise those of a cold server, and the metrics
    // account every saved position. Sequential requests on one worker
    // make the hit pattern fully deterministic.
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            prefix_cache: 4,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: std::time::Duration::from_millis(10),
        },
        ..Default::default()
    })
    .expect("server start");
    let vocab = server.vocab;
    let mut rng = Pcg32::new(42);
    let prefix: Vec<i32> = (0..8).map(|_| rng.below(vocab as u32) as i32).collect();
    // tails diverge at their first token, so the common prefix is
    // exactly the shared system prompt
    let mut win_a = prefix.clone();
    win_a.extend([5i32, 9, 2, 6]);
    let mut win_b = prefix.clone();
    win_b.extend([7i32, 1, 8, 3, 4, 0]);
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    // A: cold (store empty), donates its window on completion
    let got_a = server.infer(win_a.clone()).expect("cold request");
    assert_eq!(got_a, golden.score(&win_a).0, "cold serving drifted");
    // B: shares the 8-token prefix -> splice, remainder stepped
    let got_b = server.infer(win_b.clone()).expect("prefix-hit request");
    assert_eq!(got_b, golden.score(&win_b).0, "spliced logits drifted");
    // C: A's exact window -> all but the last position from the cache
    let got_c = server.infer(win_a.clone()).expect("full-window hit");
    assert_eq!(got_c, got_a, "cache replay of an identical window drifted");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.prefix_lookups, 3);
    assert_eq!(snap.prefix_hits, 2, "B and C must hit");
    let saved = (prefix.len() + win_a.len() - 1) as u64;
    assert_eq!(snap.prefix_positions_saved, saved);
    assert!(snap.prefix_hit_rate > 0.6 && snap.prefix_hit_rate < 0.7);
    // sim_tokens counts chip-replayed positions only: cache hits must
    // have skipped exactly `saved` prefill positions
    let total = (win_a.len() * 2 + win_b.len()) as u64;
    assert_eq!(snap.sim_tokens, total - saved);
    assert_eq!(snap.errors, 0);
    server.shutdown();
}

#[test]
fn prefix_cache_off_is_byte_identical_and_never_looks_up() {
    // the knob's off position is the PR-4 path: same windows through a
    // cacheless server must produce byte-identical logits and record
    // zero lookups
    let mk = |entries: usize| {
        InferenceServer::start(ServerConfig {
            backend: Backend::CimSim(CimSimConfig {
                prefix_cache: entries,
                ..Default::default()
            }),
            ..Default::default()
        })
        .expect("server start")
    };
    let cold = mk(0);
    let cached = mk(8);
    let mut rng = Pcg32::new(77);
    let prefix: Vec<i32> = (0..6).map(|_| rng.below(cold.vocab as u32) as i32).collect();
    for i in 0..4 {
        let mut w = prefix.clone();
        w.extend((0..3 + i).map(|_| rng.below(cold.vocab as u32) as i32));
        let a = cold.infer(w.clone()).expect("cold inference");
        let b = cached.infer(w).expect("cached inference");
        assert_eq!(a, b, "request {i}: prefix reuse changed the scores");
    }
    let snap = cold.metrics.snapshot();
    assert_eq!(snap.prefix_lookups, 0, "disabled cache must never look up");
    assert_eq!(snap.prefix_positions_saved, 0);
    let snap = cached.metrics.snapshot();
    assert!(snap.prefix_hits > 0, "shared-prefix workload never hit");
    assert!(snap.prefix_positions_saved > 0);
    cold.shutdown();
    cached.shutdown();
}

#[test]
fn dropped_clients_are_cancelled_without_disturbing_live_ones() {
    // ISSUE-8 satellite: a client that abandons its PendingResponse
    // must be counted as a cancellation and release its slot early —
    // and a live neighbour's reply stays bit-identical. prefill_chunk=1
    // keeps every window many steps long, so no doomed request can
    // finish before its handle is dropped.
    let server = InferenceServer::start(ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            prefill_chunk: 1,
            ..Default::default()
        }),
        policy: BatchPolicy {
            max_batch: 2,
            max_delay: std::time::Duration::from_millis(10),
        },
        ..Default::default()
    })
    .expect("server start");
    let seq = server.seq;
    let vocab = server.vocab;
    let mut doomed = Vec::new();
    for i in 0..5u64 {
        let mut rng = Pcg32::new(6000 + i);
        let w: Vec<i32> = (0..seq).map(|_| rng.below(vocab as u32) as i32).collect();
        doomed.push(server.submit(w).expect("submit"));
    }
    drop(doomed); // all five clients vanish before any window completes
    // a live request through the same pool still serves exactly
    let mut rng = Pcg32::new(8888);
    let live: Vec<i32> = (0..12).map(|_| rng.below(vocab as u32) as i32).collect();
    let got = server.infer(live.clone()).expect("live inference");
    let mut golden = DecodeEngine::reference(DecodeModel::synth(
        monarch_cim::model::ModelConfig::tiny(),
        2025,
    ));
    assert_eq!(got, golden.score(&live).0, "cancellations disturbed a live client");
    let metrics = server.metrics.clone();
    server.shutdown(); // drains the queue: remaining dead requests are swept
    let snap = metrics.snapshot();
    assert_eq!(snap.cancellations, 5, "every dropped client counts once");
    assert_eq!(snap.errors, 0, "cancellation is not an error");
}

#[test]
fn startup_fails_cleanly_without_artifacts() {
    // The PJRT backend must report a startup error (missing artifacts /
    // stubbed runtime), never hang or panic.
    let cfg = ServerConfig {
        artifacts_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
        backend: Backend::Pjrt,
        ..Default::default()
    };
    let err = match InferenceServer::start(cfg) {
        Err(e) => e,
        Ok(_) => panic!("startup must fail without artifacts"),
    };
    assert!(err.to_string().contains("artifacts"), "{err}");
}

#[test]
fn cimsim_rejects_non_decoder_models() {
    let cfg = ServerConfig {
        backend: Backend::CimSim(CimSimConfig {
            model: monarch_cim::model::ModelConfig::bert_large(),
            ..Default::default()
        }),
        ..Default::default()
    };
    let err = match InferenceServer::start(cfg) {
        Err(e) => e,
        Ok(_) => panic!("encoder-only model must be rejected"),
    };
    assert!(err.to_string().contains("decoder-only"), "{err}");
}
