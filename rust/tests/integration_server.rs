//! Integration: the batching inference server over the PJRT runtime —
//! concurrency, batching behaviour, golden-output fidelity, error paths
//! and clean shutdown. Requires `make artifacts`.

use monarch_cim::coordinator::{InferenceServer, ServerConfig};
use monarch_cim::coordinator::batching::BatchPolicy;
use monarch_cim::util::json::Json;
use monarch_cim::util::rng::Pcg32;

fn start_server() -> InferenceServer {
    InferenceServer::start(ServerConfig::default())
        .expect("server start — run `make artifacts` first")
}

#[test]
fn serves_concurrent_requests() {
    let server = start_server();
    let seq = server.seq;
    let vocab = server.vocab as u32;
    std::thread::scope(|scope| {
        for i in 0..24u64 {
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Pcg32::new(i);
                let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
                let logits = srv.infer(toks).expect("inference");
                assert_eq!(logits.len(), seq * srv.vocab);
                assert!(logits.iter().all(|v| v.is_finite()));
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 24);
    assert!(snap.batches <= 24);
    assert_eq!(snap.errors, 0);
    server.shutdown();
}

#[test]
fn batching_actually_groups() {
    let server = InferenceServer::start(ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(30),
        },
        ..Default::default()
    })
    .expect("server start");
    let seq = server.seq;
    std::thread::scope(|scope| {
        for _ in 0..16 {
            let srv = &server;
            scope.spawn(move || {
                let toks = vec![1i32; seq];
                srv.infer(toks).expect("inference");
            });
        }
    });
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 16);
    assert!(
        snap.mean_batch > 1.0,
        "expected batching, got mean batch {}",
        snap.mean_batch
    );
    server.shutdown();
}

#[test]
fn server_output_matches_python_golden() {
    let golden_text =
        std::fs::read_to_string("artifacts/tiny_lm_golden.json").expect("golden");
    let golden = Json::parse(&golden_text).unwrap();
    let tokens: Vec<i32> = golden.get("tokens").unwrap().as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect();
    let server = start_server();
    let logits = server.infer(tokens).expect("inference");
    let want_sum = golden.get("logits_sum").unwrap().as_f64().unwrap();
    let got_sum: f64 = logits.iter().map(|&v| v as f64).sum();
    assert!(
        (got_sum - want_sum).abs() < 1e-1 * (1.0 + want_sum.abs()),
        "sum {got_sum} vs golden {want_sum}"
    );
    server.shutdown();
}

#[test]
fn batch_identity_independent_of_batchmates() {
    // The same request must produce the same logits whether it is alone
    // in a batch or padded in with others.
    let server = start_server();
    let seq = server.seq;
    let mut rng = Pcg32::new(99);
    let toks: Vec<i32> = (0..seq)
        .map(|_| rng.below(server.vocab as u32) as i32)
        .collect();
    let solo = server.infer(toks.clone()).unwrap();
    // now issue it together with 7 concurrent others
    let mut grouped = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..8u64 {
            let srv = &server;
            let t = if i == 0 {
                toks.clone()
            } else {
                let mut r = Pcg32::new(1000 + i);
                (0..seq).map(|_| r.below(srv.vocab as u32) as i32).collect()
            };
            handles.push(scope.spawn(move || srv.infer(t).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.join().unwrap();
            if i == 0 {
                grouped = r;
            }
        }
    });
    for (a, b) in solo.iter().zip(&grouped) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
    }
    server.shutdown();
}

#[test]
fn invalid_requests_get_errors_not_hangs() {
    let server = start_server();
    // wrong length
    let err = server.infer(vec![0i32; 3]).unwrap_err();
    assert!(err.to_string().contains("invalid request"), "{err}");
    // out-of-vocab token
    let seq = server.seq;
    let mut toks = vec![0i32; seq];
    toks[0] = 1_000_000;
    assert!(server.infer(toks).is_err());
    // server still healthy afterwards
    let ok = server.infer(vec![1i32; seq]);
    assert!(ok.is_ok());
    server.shutdown();
}

#[test]
fn startup_fails_cleanly_without_artifacts() {
    let cfg = ServerConfig {
        artifacts_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
        ..Default::default()
    };
    let err = match InferenceServer::start(cfg) {
        Err(e) => e,
        Ok(_) => panic!("startup must fail without artifacts"),
    };
    assert!(err.to_string().contains("artifacts"), "{err}");
}
