//! Property tests for speculative decoding (`sim::speculate`,
//! DESIGN.md §6d): over random model geometries, mapping strategies,
//! K ∈ 1..=8 and draft configurations (layer-truncated self-drafts,
//! unrelated-seed drafts, smaller-dimension drafts) —
//!
//! * emitted token sequences are **bitwise equal** to
//!   [`DecodeEngine::generate`] on the target model (the ISSUE-5
//!   acceptance property: a draft can cost rounds, never change output);
//! * the target KV cache after rollback is bitwise equal to the plain
//!   engine's at the same length (rejected lanes leave no residue);
//! * per-round cost records sum to the honest lane count — rejected
//!   lanes included — and each lane's record equals
//!   [`decode_token_cost`] at its own KV length;
//! * [`KvCache::truncate`]-then-extend is bitwise indistinguishable
//!   from never having extended (the rollback primitive itself).

use monarch_cim::sim::decode::{DecodeEngine, DecodeModel};
use monarch_cim::sim::speculate::{self_draft_model, SpeculativeEngine};
use monarch_cim::sim::trace::decode_token_cost;
use monarch_cim::util::prop::forall;

mod common;

#[test]
fn prop_speculative_tokens_bit_identical_to_greedy() {
    forall("speculative decode == plain greedy (bitwise)", 8, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let k = g.usize(1, 8);
        // three draft families: layer-truncated self-draft (partial
        // agreement), unrelated seed (mostly rejections — rollback
        // exercised), smaller-dimension draft (different geometry)
        let draft_kind = g.usize(0, 2);
        let draft = match draft_kind {
            0 => self_draft_model(&cfg, seed, g.usize(1, cfg.dec_layers)),
            1 => DecodeModel::synth(cfg.clone(), seed.wrapping_add(1)),
            _ => {
                let mut dcfg = cfg.clone();
                dcfg.d_model = 16;
                dcfg.n_heads = 2;
                dcfg.d_ff = 32;
                DecodeModel::synth(dcfg, seed.wrapping_add(2))
            }
        };
        let plen = g.usize(1, 6);
        let n_tokens = g.usize(1, 6);
        let prompt: Vec<i32> = (0..plen)
            .map(|i| ((i * 13 + 5) % cfg.vocab) as i32)
            .collect();
        let mut spec = SpeculativeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            draft,
            params.clone(),
            strategy,
            k,
        );
        let r = spec.generate(&prompt, n_tokens);
        let mut plain = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        let want = plain.generate(&prompt, n_tokens);
        assert_eq!(
            r.tokens, want.tokens,
            "{strategy:?} K={k} draft_kind={draft_kind}: speculative tokens \
             diverged from plain greedy decode"
        );

        // KV after rollback == plain engine at the same length (the
        // spec engine never feeds the final emitted token, so its cache
        // is exactly one position shorter)
        let spec_kv = spec.kv_cache();
        assert_eq!(spec_kv.len(), plen + n_tokens - 1, "unexpected cache length");
        let plain_kv = plain.kv_cache();
        for l in 0..cfg.dec_layers {
            for pos in 0..spec_kv.len() {
                assert_eq!(
                    spec_kv.key(l, pos),
                    plain_kv.key(l, pos),
                    "{strategy:?} K={k} layer {l} pos {pos}: rollback left key residue"
                );
                assert_eq!(
                    spec_kv.value(l, pos),
                    plain_kv.value(l, pos),
                    "{strategy:?} K={k} layer {l} pos {pos}: rollback left value residue"
                );
            }
        }

        // honest lane accounting: every verify lane — accepted or
        // rejected — has exactly one per-position record, and each round
        // record matches decode_token_cost at the lane's own KV length
        let fed: usize = r.rounds.iter().map(|rd| rd.lanes).sum();
        assert_eq!(
            r.per_position.len(),
            plen + fed,
            "{strategy:?} K={k}: per-position records != prompt + verify lanes"
        );
        let mm = spec.mapping().expect("on-chip engine has a mapping");
        let mut flat = r.per_position[plen..].iter();
        for (ri, rd) in r.rounds.iter().enumerate() {
            assert_eq!(rd.lanes, rd.proposed + 1, "round {ri}: lane count");
            assert!(rd.accepted <= rd.proposed, "round {ri}: accepted > proposed");
            assert!(rd.proposed <= k, "round {ri}: proposed > K");
            assert_eq!(rd.verify.per_lane.len(), rd.lanes, "round {ri}: bill size");
            for (i, c) in rd.verify.per_lane.iter().enumerate() {
                let want_cost =
                    decode_token_cost(&cfg, mm, &params, rd.base_kv + i + 1);
                assert_eq!(
                    c.latency, want_cost.latency,
                    "round {ri} lane {i}: latency record drifted"
                );
                assert_eq!(
                    c.energy, want_cost.energy,
                    "round {ri} lane {i}: energy record drifted"
                );
                // the slot-trace record (flattened) is the same bill
                let traced = flat.next().expect("trace shorter than lanes");
                assert_eq!(traced.latency, want_cost.latency, "trace latency");
                assert_eq!(traced.energy, want_cost.energy, "trace energy");
            }
        }
        assert!(flat.next().is_none(), "trace longer than the rounds' lanes");
    });
}

#[test]
fn prop_perfect_self_draft_never_rejects() {
    // a full-depth self-draft is the target bit for bit, so greedy
    // acceptance takes every proposal: acceptance rate 1.0 and > 1
    // token per round whenever K and the request allow it
    forall("full self-draft accepts everything", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::monarch_strategy(g);
        let k = g.usize(1, 4);
        let prompt: Vec<i32> = (0..g.usize(1, 4))
            .map(|i| ((i * 29 + 3) % cfg.vocab) as i32)
            .collect();
        // n >= 3 so the first round always has room for >= 1 proposal
        let n_tokens = g.usize(3, 8);
        let mut spec = SpeculativeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            self_draft_model(&cfg, seed, cfg.dec_layers),
            params.clone(),
            strategy,
            k,
        );
        let r = spec.generate(&prompt, n_tokens);
        assert!(r.total_proposed() > 0, "no proposals despite n_tokens >= 2");
        assert_eq!(
            r.total_accepted(),
            r.total_proposed(),
            "{strategy:?} K={k}: a perfect draft was rejected"
        );
        assert_eq!(r.acceptance_rate(), 1.0);
        assert!(r.tokens_per_round() > 1.0, "no speculative win");
        let mut plain = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        assert_eq!(r.tokens, plain.generate(&prompt, n_tokens).tokens);
    });
}

#[test]
fn mismatched_draft_forces_midwindow_rejections() {
    // deterministic rollback exercise: an unrelated-seed draft disagrees
    // with the target almost everywhere, so verify rounds reject
    // mid-window (accepted < proposed) — and the output must still be
    // bitwise the plain greedy sequence (the rollback left no trace)
    let cfg = monarch_cim::model::ModelConfig::tiny();
    let params = monarch_cim::cim::CimParams::default();
    let strategy = monarch_cim::mapping::Strategy::DenseMap;
    let mut spec = SpeculativeEngine::on_chip(
        DecodeModel::synth(cfg.clone(), 2025),
        DecodeModel::synth(cfg.clone(), 77_777),
        params.clone(),
        strategy,
        4,
    );
    let prompt = [11i32, 48, 85];
    let r = spec.generate(&prompt, 12);
    assert!(
        r.rounds.iter().any(|rd| rd.accepted < rd.proposed),
        "an unrelated draft should reject at least once"
    );
    let mut plain = DecodeEngine::on_chip(DecodeModel::synth(cfg, 2025), params, strategy);
    let want = plain.generate(&prompt, 12);
    assert_eq!(r.tokens, want.tokens, "rejection rollback corrupted the output");
}

#[test]
fn prop_kv_truncate_then_extend_is_bitwise_invisible() {
    // the rollback primitive: feed a prefix, detour through junk
    // positions, truncate back, resume — the cache and logits must be
    // bitwise what a straight-through engine produces (truncate to 0 is
    // included via cut == 0)
    forall("kv truncate+extend == straight-through", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let toks: Vec<i32> = (0..8)
            .map(|i| ((i * 13 + 5) % cfg.vocab) as i32)
            .collect();
        let cut = g.usize(0, toks.len() - 1);
        let junk_n = g.usize(1, 4);
        let mut straight = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        let mut want_last = Vec::new();
        for &t in &toks {
            want_last = straight.forward(t).to_vec();
        }
        let mut detour = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        for &t in &toks[..cut] {
            detour.forward(t);
        }
        for j in 0..junk_n {
            detour.forward(((j * 7 + 1) % cfg.vocab) as i32);
        }
        detour.truncate_kv(cut);
        assert_eq!(detour.kv_len(), cut);
        let mut got_last = Vec::new();
        for &t in &toks[cut..] {
            got_last = detour.forward(t).to_vec();
        }
        assert_eq!(
            want_last, got_last,
            "{strategy:?} cut {cut}: resumed logits drifted"
        );
        assert_eq!(straight.kv_len(), detour.kv_len());
        for l in 0..cfg.dec_layers {
            for pos in 0..toks.len() {
                assert_eq!(
                    straight.kv_cache().key(l, pos),
                    detour.kv_cache().key(l, pos),
                    "{strategy:?} layer {l} pos {pos}: key residue after rollback"
                );
                assert_eq!(
                    straight.kv_cache().value(l, pos),
                    detour.kv_cache().value(l, pos),
                    "{strategy:?} layer {l} pos {pos}: value residue after rollback"
                );
            }
        }
    });
}
