//! Property tests for shared-prefix KV reuse (`BatchDecodeEngine::
//! splice_kv` + `KvCache::clone_prefix`, DESIGN.md §6g): over random
//! model geometries, mapping strategies, prefix lengths and chunk
//! partitions, a window admitted with a spliced cached prefix is
//! **bit-identical** to cold prefill — the stepped positions' logits,
//! the full KV cache, and the cached positions' logits the server
//! would answer from the store all match a token-by-token reference
//! bitwise.
//!
//! This is the ISSUE-8 acceptance property, and it holds by
//! construction: a position's K/V depend only on the tokens up to it,
//! so under an identical leading window the donor's cached state IS
//! the state cold prefill would build. The splice changes only *who
//! computed* the prefix positions (the donor's pass, already billed),
//! never what any position computes.

use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
use monarch_cim::util::prop::forall;

mod common;

#[test]
fn prop_spliced_admission_bit_identical_to_cold_prefill() {
    // Serving shape: one chip, two slots. A donor window is scored in
    // slot A (its KV + logits play the prefix store's entry); a second
    // window sharing `p` leading tokens is admitted into slot B with
    // the donor's first `p` positions spliced in, and steps only its
    // remainder — in random chunks, while the donor still occupies the
    // chip. Every observable must match a cold token-by-token engine.
    forall("spliced admission == cold prefill", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let donor_len = g.usize(2, 12);
        let donor: Vec<i32> = (0..donor_len)
            .map(|i| ((i * 17 + 3) % cfg.vocab) as i32)
            .collect();
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            2,
        );
        // --- donor pass: score the donor window, keep its logits ---
        let d_slot = be.try_admit().unwrap();
        let mut donor_logits: Vec<f32> = Vec::new();
        let mut fed = 0usize;
        while fed < donor_len {
            let c = g.usize(1, (donor_len - fed).min(6));
            be.step_chunks(&[(d_slot, &donor[fed..fed + c])]);
            for i in 0..c {
                donor_logits.extend_from_slice(be.lane_logits(i));
            }
            fed += c;
        }
        // --- target window: shares p leading tokens with the donor ---
        let target_len = g.usize(2, 12);
        let p = g.usize(1, donor_len.min(target_len - 1));
        let mut target: Vec<i32> = donor[..p].to_vec();
        target.extend((0..target_len - p).map(|i| ((i * 29 + 11) % cfg.vocab) as i32));
        // the store's hit: a cloned prefix of the donor's cache (what
        // PrefixStore::lookup hands the worker)
        let hit_kv = be.kv(d_slot).clone_prefix(p);
        let t_slot = be.try_admit().unwrap();
        be.splice_kv(t_slot, &hit_kv, p);
        assert_eq!(be.kv_len(t_slot), p, "splice seeds exactly p positions");
        // step the remainder in random chunks, collecting its logits
        let mut stepped_logits: Vec<f32> = Vec::new();
        let mut fed = p;
        while fed < target_len {
            let c = g.usize(1, (target_len - fed).min(6));
            be.step_chunks(&[(t_slot, &target[fed..fed + c])]);
            for i in 0..c {
                stepped_logits.extend_from_slice(be.lane_logits(i));
            }
            fed += c;
        }
        // --- cold reference: token-by-token, no reuse anywhere ---
        let mut cold = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        let mut cold_logits: Vec<f32> = Vec::new();
        for &t in &target {
            cold_logits.extend_from_slice(cold.forward(t));
        }
        // cached positions: the logits the server answers from the
        // store are the donor's — bitwise the cold window's, because
        // the windows agree on every token up to p
        assert_eq!(
            &donor_logits[..p * cfg.vocab],
            &cold_logits[..p * cfg.vocab],
            "{strategy:?} prefix {p}: cached logits drift from cold prefill"
        );
        // stepped positions: the spliced slot continues bit-identically
        assert_eq!(
            stepped_logits.as_slice(),
            &cold_logits[p * cfg.vocab..],
            "{strategy:?} prefix {p}: post-splice logits drift from cold prefill"
        );
        // the full KV cache matches cold prefill at every layer/position
        assert_eq!(be.kv_len(t_slot), cold.kv_len());
        for l in 0..cfg.dec_layers {
            for pos in 0..target_len {
                assert_eq!(
                    be.kv(t_slot).key(l, pos),
                    cold.kv_cache().key(l, pos),
                    "{strategy:?} layer {l} pos {pos} (prefix {p}): key drifted"
                );
                assert_eq!(
                    be.kv(t_slot).value(l, pos),
                    cold.kv_cache().value(l, pos),
                    "{strategy:?} layer {l} pos {pos} (prefix {p}): value drifted"
                );
            }
        }
    });
}

#[test]
fn prop_full_window_match_still_steps_the_last_position() {
    // The store caps a hit at window_len - 1 (recompute the last
    // token). Pin the engine side of that contract: splicing all but
    // the last position and stepping exactly one token reproduces the
    // cold window bitwise — the smallest possible post-splice step.
    forall("p = len-1 splice steps one position", 6, |g| {
        let cfg = common::random_decoder_cfg(g);
        let params = common::chip_params(g, &[16, 32]);
        if !common::fits_array(&cfg, &params) {
            return;
        }
        let seed = common::seed(g);
        let strategy = common::any_strategy(g);
        let len = g.usize(2, 10);
        let window: Vec<i32> = (0..len)
            .map(|i| ((i * 23 + 7) % cfg.vocab) as i32)
            .collect();
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
            2,
        );
        // donor: the identical window, fully scored
        let d_slot = be.try_admit().unwrap();
        be.step_chunks(&[(d_slot, &window)]);
        let hit_kv = be.kv(d_slot).clone_prefix(len - 1);
        // target: same window, spliced to len-1, one stepped position
        let t_slot = be.try_admit().unwrap();
        be.splice_kv(t_slot, &hit_kv, len - 1);
        be.step_chunks(&[(t_slot, &window[len - 1..])]);
        let mut cold = DecodeEngine::on_chip(
            DecodeModel::synth(cfg.clone(), seed),
            params.clone(),
            strategy,
        );
        let mut last = Vec::new();
        for &t in &window {
            last = cold.forward(t).to_vec();
        }
        assert_eq!(
            be.lane_logits(0),
            last.as_slice(),
            "{strategy:?}: recomputed last position drifted"
        );
        assert_eq!(be.kv_len(t_slot), cold.kv_len());
    });
}
