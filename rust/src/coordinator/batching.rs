//! Dynamic request batcher: groups inference requests up to a max batch
//! size or max linger delay, whichever comes first (the standard
//! serving-system batching policy; std-thread + channel implementation
//! since the offline image has no tokio — see DESIGN.md §1).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
        }
    }
}

/// Collect the next batch from `rx`: blocks for the first item, then
/// lingers up to `max_delay` (or until `max_batch`) for more. Returns
/// `None` when the channel is closed and drained.
///
/// Liveness audit (ISSUE 7): this gather-then-execute loop is **live**
/// — it drives the PJRT worker (`server::run_pjrt_worker`), whose AOT
/// artifacts execute whole fixed-size batches and therefore want
/// linger-batched admission. The CIM-sim worker intentionally does NOT
/// use it: continuous batching admits each request into a slot the
/// moment one frees up (no linger), so batching there is per-step lane
/// grouping, not arrival grouping. Keep both paths.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_delay;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Pick the smallest available executable batch size >= n (AOT artifacts
/// are compiled for fixed batch sizes; inputs are padded up).
pub fn pick_bucket(available: &[usize], n: usize) -> Option<usize> {
    available.iter().copied().filter(|&b| b >= n).min()
}

/// Lane budget of one chunked continuous-batching step (CIM-sim
/// backend): the batched replay carries at most this many position
/// lanes per step. Every in-flight request must keep a lane even at
/// full occupancy (`capacity` decode lanes are never starved by a
/// neighbour's prefill), and when slots are idle a prefilling request
/// may widen up to its configured `chunk` — so the budget is the larger
/// of the two, and prefill parallelism is automatically traded away
/// exactly when the chip is busy serving decode lanes.
pub fn prefill_lane_budget(capacity: usize, chunk: usize) -> usize {
    capacity.max(chunk).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 3,
            max_delay: Duration::from_millis(50),
        };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2]);
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![3, 4]);
    }

    #[test]
    fn returns_none_on_closed_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn linger_delay_bounds_wait() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy {
            max_batch: 10,
            max_delay: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(pick_bucket(&[1, 4, 8], 1), Some(1));
        assert_eq!(pick_bucket(&[1, 4, 8], 3), Some(4));
        assert_eq!(pick_bucket(&[1, 4, 8], 8), Some(8));
        assert_eq!(pick_bucket(&[1, 4, 8], 9), None);
    }

    #[test]
    fn prefill_budget_never_starves_decode_lanes() {
        // at least one lane per slot, regardless of chunk configuration
        assert_eq!(prefill_lane_budget(8, 4), 8);
        // a wide chunk can use idle capacity
        assert_eq!(prefill_lane_budget(2, 16), 16);
        assert_eq!(prefill_lane_budget(0, 0), 1);
    }
}
