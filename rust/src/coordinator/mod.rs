//! Layer-3 coordinator: the end-to-end framework pipeline (D2S -> map ->
//! schedule -> simulate), the threaded batching inference server over the
//! PJRT runtime, dynamic batching policy and serving metrics.

pub mod batching;
pub mod dse;
pub mod metrics;
pub mod pipeline;
pub mod server;

pub use pipeline::{run_pipeline, PipelineConfig, PipelineResult};
pub use server::{InferenceServer, ServerConfig};
