//! Layer-3 coordinator: the end-to-end framework pipeline (D2S -> map ->
//! schedule -> simulate), the threaded batching inference server with
//! selectable execution backend (PJRT artifacts or the emulated-crossbar
//! CIM simulator), dynamic batching policy and serving metrics.

pub mod batching;
pub mod dse;
pub mod metrics;
pub mod pipeline;
pub mod server;

pub use pipeline::{run_pipeline, PipelineConfig, PipelineResult};
pub use server::{Backend, CimSimConfig, InferenceServer, ServerConfig};
