//! Layer-3 coordinator: the end-to-end framework pipeline (D2S -> map ->
//! schedule -> simulate), the threaded batching inference server with
//! selectable execution backend (PJRT artifacts or the emulated-crossbar
//! CIM simulator), dynamic batching policy and serving metrics.

pub mod batching;
pub mod dse;
pub mod framework;
pub mod metrics;
mod prefix;
pub mod server;
pub mod tracing;

pub use framework::{run_pipeline, PipelineConfig, PipelineResult};
pub use server::{Backend, CimSimConfig, InferenceServer, PendingResponse, ServerConfig};
pub use tracing::Tracer;
