//! Shared-prefix KV cache for the serving front end (DESIGN.md §6g).
//!
//! At millions-of-users scale most windows open with the same tokens —
//! system prompts, few-shot templates — so the dominant prefill work is
//! re-deriving K/V state the chip already computed for an earlier
//! request. Each CIM-sim worker keeps a [`PrefixStore`]: completed
//! windows donate their KV cache and per-position logits, and an
//! incoming window is matched against the store by **longest common
//! token prefix**. On a hit, the shared positions are spliced into the
//! fresh slot (`BatchDecodeEngine::splice_kv`) and their logits are
//! answered straight from the store — the chip never replays them.
//!
//! Keying is the token sequence itself (the only thing K/V depend on —
//! same model, same weights, so same tokens ⇒ bitwise same state;
//! `tests/prop_prefix_cache.rs` pins the splice against cold prefill).
//! The store is per-worker and single-threaded — no locks on the
//! serving path; matching is a linear scan over at most `cap` entries.
//!
//! A hit is always capped at `window.len() - 1`: the last position is
//! re-stepped even on a full-window match, so every admission performs
//! at least one replay (the engine's step contract) — the vLLM-style
//! "recompute the last token" rule.

use crate::sim::prefill::KvCache;

/// One cached donor: the scored token window, its full KV cache and the
/// per-position logits (`tokens.len() * vocab`) the server replied with.
struct PrefixEntry {
    tokens: Vec<i32>,
    kv: KvCache,
    logits: Vec<f32>,
    /// Last-touched stamp (insert or hit) for LRU eviction.
    stamp: u64,
}

/// A prefix-cache hit: cloned K/V and logits for `positions` leading
/// tokens of the looked-up window.
pub(crate) struct PrefixHit {
    pub kv: KvCache,
    pub logits: Vec<f32>,
    pub positions: usize,
}

/// Per-worker shared-prefix store with an LRU entry cap.
pub(crate) struct PrefixStore {
    entries: Vec<PrefixEntry>,
    cap: usize,
    vocab: usize,
    clock: u64,
}

/// Length of the common leading run of `a` and `b`.
fn common_prefix(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl PrefixStore {
    pub fn new(cap: usize, vocab: usize) -> Self {
        Self {
            entries: Vec::with_capacity(cap),
            cap,
            vocab,
            clock: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest-common-prefix lookup for an incoming window. Returns the
    /// best hit (≥ 1 position, capped at `window.len() - 1` so at least
    /// one position is always stepped), or `None` on a miss.
    pub fn lookup(&mut self, window: &[i32]) -> Option<PrefixHit> {
        let budget = window.len().saturating_sub(1);
        let (idx, lcp) = self
            .entries
            .iter()
            .enumerate()
            .map(|(i, e)| (i, common_prefix(&e.tokens, window).min(budget)))
            .max_by_key(|&(_, lcp)| lcp)?;
        if lcp == 0 {
            return None;
        }
        let stamp = self.tick();
        let e = &mut self.entries[idx];
        e.stamp = stamp;
        Some(PrefixHit {
            kv: e.kv.clone_prefix(lcp),
            logits: e.logits[..lcp * self.vocab].to_vec(),
            positions: lcp,
        })
    }

    /// Donate one completed window: its tokens, final KV cache and the
    /// full per-position logits. An entry already covering `tokens` (it
    /// has them as a prefix) is only freshened; an entry `tokens`
    /// covers is replaced by the longer donor; otherwise the window is
    /// inserted, evicting the least-recently-touched entry at cap.
    pub fn insert(&mut self, tokens: &[i32], kv: &KvCache, logits: &[f32]) {
        if self.cap == 0 || tokens.is_empty() {
            return;
        }
        debug_assert_eq!(kv.len(), tokens.len(), "donor KV spans the window");
        debug_assert_eq!(logits.len(), tokens.len() * self.vocab);
        let stamp = self.tick();
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.tokens.len() >= tokens.len() && e.tokens.starts_with(tokens))
        {
            e.stamp = stamp; // already covered by a longer (or equal) donor
            return;
        }
        let entry = PrefixEntry {
            tokens: tokens.to_vec(),
            kv: kv.clone_prefix(kv.len()),
            logits: logits.to_vec(),
            stamp,
        };
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| tokens.starts_with(&e.tokens))
        {
            *e = entry; // strictly longer donor supersedes its prefix
            return;
        }
        if self.entries.len() == self.cap {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("cap > 0 so the store is non-empty here");
            self.entries.swap_remove(lru);
        }
        self.entries.push(entry);
    }

    /// Entries currently held (test observability; the serving path
    /// never needs the count).
    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A donor cache with recognizable per-position values: position
    /// `p` of layer `l` holds `[l*100 + p]` so splices are traceable.
    fn kv_for(tokens: &[i32], layers: usize) -> KvCache {
        let mut kv = KvCache::new(layers);
        for l in 0..layers {
            for (p, _) in tokens.iter().enumerate() {
                kv.push(l, vec![(l * 100 + p) as f32], vec![-((l * 100 + p) as f32)]);
            }
        }
        kv
    }

    fn logits_for(tokens: &[i32], vocab: usize) -> Vec<f32> {
        (0..tokens.len() * vocab).map(|i| i as f32).collect()
    }

    #[test]
    fn lookup_finds_longest_common_prefix() {
        let mut store = PrefixStore::new(4, 2);
        let a = [1, 2, 3, 4];
        let b = [1, 2, 9, 9, 9];
        store.insert(&a, &kv_for(&a, 1), &logits_for(&a, 2));
        store.insert(&b, &kv_for(&b, 1), &logits_for(&b, 2));
        // window shares 3 tokens with `a`, 2 with `b` → `a` wins
        let hit = store.lookup(&[1, 2, 3, 7, 7]).expect("hit");
        assert_eq!(hit.positions, 3);
        assert_eq!(hit.kv.len(), 3);
        assert_eq!(hit.logits.len(), 3 * 2);
        assert_eq!(hit.kv.key(0, 2), &[2.0]);
        // no shared opening token → miss
        assert!(store.lookup(&[5, 1, 2]).is_none());
    }

    #[test]
    fn full_window_match_recomputes_the_last_token() {
        let mut store = PrefixStore::new(4, 1);
        let w = [3, 1, 4, 1, 5];
        store.insert(&w, &kv_for(&w, 1), &logits_for(&w, 1));
        // an identical window must still step ≥ 1 position
        let hit = store.lookup(&w).expect("hit");
        assert_eq!(hit.positions, w.len() - 1);
        // a 1-token window can never hit (nothing would be stepped)
        assert!(store.lookup(&w[..1]).is_none());
    }

    #[test]
    fn insert_dedups_covered_prefixes_both_ways() {
        let mut store = PrefixStore::new(4, 1);
        let long = [1, 2, 3, 4];
        store.insert(&long, &kv_for(&long, 1), &logits_for(&long, 1));
        // a prefix of an existing donor adds nothing
        store.insert(&long[..2], &kv_for(&long[..2], 1), &logits_for(&long[..2], 1));
        assert_eq!(store.len(), 1);
        // a longer window supersedes the entry it extends
        let longer = [1, 2, 3, 4, 5, 6];
        store.insert(&longer, &kv_for(&longer, 1), &logits_for(&longer, 1));
        assert_eq!(store.len(), 1);
        let hit = store.lookup(&[1, 2, 3, 4, 5, 6, 7]).expect("hit");
        assert_eq!(hit.positions, 6);
    }

    #[test]
    fn cap_evicts_least_recently_touched() {
        let mut store = PrefixStore::new(2, 1);
        let a = [10, 11];
        let b = [20, 21];
        let c = [30, 31];
        store.insert(&a, &kv_for(&a, 1), &logits_for(&a, 1));
        store.insert(&b, &kv_for(&b, 1), &logits_for(&b, 1));
        // touch `a` so `b` is the LRU victim
        assert!(store.lookup(&[10, 11, 12]).is_some());
        store.insert(&c, &kv_for(&c, 1), &logits_for(&c, 1));
        assert_eq!(store.len(), 2);
        assert!(store.lookup(&[10, 11, 12]).is_some(), "a survived");
        assert!(store.lookup(&[20, 21, 22]).is_none(), "b evicted");
        assert!(store.lookup(&[30, 31, 32]).is_some(), "c inserted");
    }

    #[test]
    fn zero_cap_disables_the_store() {
        let mut store = PrefixStore::new(0, 1);
        let w = [1, 2, 3];
        store.insert(&w, &kv_for(&w, 1), &logits_for(&w, 1));
        assert_eq!(store.len(), 0);
        assert!(store.lookup(&w).is_none());
    }
}
