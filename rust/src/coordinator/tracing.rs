//! End-to-end request tracing (DESIGN.md §6h): every served request
//! carries a span tree `enqueue → admit → [prefix-splice] →
//! prefill-chunk* → decode-step* / spec-round* → reply|cancel`, recorded
//! as fixed-size [`Event`]s in bounded ring buffers and exported two
//! ways — Chrome/Perfetto trace-event JSON ([`perfetto_json`]) and a
//! per-request breakdown table ([`breakdown_table`]) that decomposes
//! TTFT into queue wait + prefill + splice-saved work.
//!
//! Cost discipline: the serving hot path records **one event per step
//! boundary per in-flight slot, never per lane**. Each worker owns its
//! [`WorkerTrace`] ring outright — recording is a bounds-checked array
//! write, no lock, no allocation — and delivers the ring to the shared
//! [`Tracer`] only when the worker exits (on [`Drop`]). Submit-side
//! events (enqueue, queue depth) go through a mutex-protected shared
//! ring, which is off the worker hot path by construction. With tracing
//! disabled (`CimSimConfig::trace == None`) the worker holds no ring at
//! all and every trace site is a skipped `if let` on a `None` — zero
//! allocation, zero locking, and the traced run is bit-identical to the
//! untraced one because tracing never touches engine state
//! (`tests/prop_tracing.rs`).
//!
//! Every span carries **both clocks**: wall-clock µs since the tracer
//! epoch (what the host actually spent, queue wait included) and the
//! *modeled* chip time of the work inside the span (`sim_ns`, summed
//! from the engine's per-position [`Cost`] records). The Perfetto
//! export keeps the axes on separate tracks: wall-time worker/request
//! tracks, and a modeled-sim-time track for the pipeline-stage windows
//! of a sharded engine.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cim::energy::Cost;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::table::Table;

/// What one trace event marks. Request-scoped kinds form the span tree;
/// `WorkerStep`/`StageStep` are execution-track spans; the remaining
/// kinds are counter samples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the queue (instant; `a` = prompt length).
    Enqueue,
    /// Queue-wait span: starts at submission, ends when a worker admits
    /// the request into a slot (`a` = slot, `b` = prompt length).
    Admit,
    /// Shared-prefix splice at admission (instant; `a` = positions
    /// answered from the cache).
    PrefixSplice,
    /// Multi-position prompt-ingestion chunk (`a` = positions fed,
    /// `b` = window position before the chunk).
    PrefillChunk,
    /// Single-position decode-pace step (`a` = 1, `b` = position).
    DecodeStep,
    /// Speculative verify round (`a` = positions fed, `b` = position).
    SpecRound,
    /// Request replied (instant; `a` = positions replayed on the chip,
    /// `b` = window length, `sim_ns` = the request's modeled total).
    Reply,
    /// Request cancelled — client vanished (instant; `a` = positions
    /// fed before the release).
    Cancel,
    /// One whole engine step on a worker (`a` = lanes fed, `b` = active
    /// slots, `sim_ns` = modeled chip time of the step).
    WorkerStep,
    /// Occupancy counter sample (`a` = occupied, `b` = capacity).
    Occupancy,
    /// Queue-depth counter sample (`a` = queued requests).
    QueueDepth,
    /// Prefix-cache counter sample (`a` = hits, `b` = lookups, both
    /// cumulative for the recording worker).
    PrefixHitRate,
    /// One pipeline-stage analog window of a sharded engine (`a` =
    /// stage, `b` = microbatch). Unlike every other kind, `t_start_us`/
    /// `t_end_us` sit on the **modeled sim-time axis**: µs of
    /// accumulated pipeline span, not wall clock.
    StageStep,
}

/// One fixed-size trace record. `Copy` so ring writes are plain array
/// stores; field meaning per kind is documented on [`EventKind`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: EventKind,
    /// Request id ([`Tracer::next_request_id`]; 0 when not
    /// request-scoped).
    pub request: u64,
    pub worker: u32,
    /// Span start/end in µs since the tracer epoch — wall clock for
    /// every kind except [`EventKind::StageStep`] (modeled sim time).
    pub t_start_us: f64,
    pub t_end_us: f64,
    /// Modeled chip time attributed to the span (ns; 0.0 when n/a).
    pub sim_ns: f64,
    pub a: u32,
    pub b: u32,
}

impl Event {
    /// Instant event: a zero-width span at `t_us`.
    pub fn at(kind: EventKind, request: u64, worker: u32, t_us: f64) -> Event {
        Event::span(kind, request, worker, t_us, t_us)
    }

    /// Span event over `[t0_us, t1_us]`.
    pub fn span(kind: EventKind, request: u64, worker: u32, t0_us: f64, t1_us: f64) -> Event {
        Event {
            kind,
            request,
            worker,
            t_start_us: t0_us,
            t_end_us: t1_us,
            sim_ns: 0.0,
            a: 0,
            b: 0,
        }
    }

    /// Attach the kind-specific payload fields (see [`EventKind`]).
    pub fn ab(mut self, a: u32, b: u32) -> Event {
        self.a = a;
        self.b = b;
        self
    }

    /// Attach the modeled chip time (ns).
    pub fn sim(mut self, ns: f64) -> Event {
        self.sim_ns = ns;
        self
    }
}

/// Bounded event buffer: overwrites the oldest record once full and
/// counts what it dropped, so a trace of any length holds constant
/// memory (the same discipline as the metrics histograms).
#[derive(Debug)]
pub struct Ring {
    buf: Vec<Event>,
    capacity: usize,
    /// Oldest element once wrapped (`buf[head]`).
    head: usize,
    dropped: u64,
}

impl Ring {
    fn new(capacity: usize) -> Self {
        Self {
            // bound the eager reservation; the buffer may never fill
            buf: Vec::with_capacity(capacity.min(1024)),
            capacity: capacity.max(1),
            head: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Retained events, oldest first.
    fn events(&self) -> impl Iterator<Item = &Event> {
        self.buf[self.head..].iter().chain(&self.buf[..self.head])
    }

    fn len(&self) -> usize {
        self.buf.len()
    }
}

/// The shared trace sink: hands out request ids and per-worker rings,
/// collects delivered rings, and merges everything for export. One
/// `Arc<Tracer>` is threaded through `CimSimConfig`; the CLI keeps its
/// own clone to export from after shutdown.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    /// Ring capacity handed to each worker (and the shared ring).
    capacity: usize,
    next_request: AtomicU64,
    /// Submit-side events (enqueue, queue depth) — mutex-protected, but
    /// only touched at submission, never on the worker step loop.
    shared: Mutex<Ring>,
    /// Rings delivered by exiting workers ([`WorkerTrace::drop`]).
    collected: Mutex<Vec<Ring>>,
}

impl Tracer {
    /// `capacity` bounds every ring (per worker, and the submit-side
    /// one); the oldest events are overwritten beyond it.
    pub fn new(capacity: usize) -> Self {
        Self {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            next_request: AtomicU64::new(0),
            shared: Mutex::new(Ring::new(capacity)),
            collected: Mutex::new(Vec::new()),
        }
    }

    /// Wall-clock µs since the tracer epoch.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// µs since the epoch of an instant captured elsewhere (request
    /// submission times; saturates to 0 for pre-epoch instants).
    pub fn us_of(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.epoch).as_secs_f64() * 1e6
    }

    /// Fresh request id (1-based; 0 means "untraced / not a request").
    pub fn next_request_id(&self) -> u64 {
        self.next_request.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record a submit-side event into the shared ring (off the worker
    /// hot path — workers use their own [`WorkerTrace`]).
    pub fn record(&self, ev: Event) {
        self.shared.lock().unwrap().push(ev);
    }

    /// A worker-owned ring; recording through it is lock-free. The ring
    /// is delivered back here when the `WorkerTrace` drops.
    pub fn worker(self: &Arc<Self>, worker: u32) -> WorkerTrace {
        WorkerTrace {
            tracer: self.clone(),
            ring: Ring::new(self.capacity),
            worker,
        }
    }

    /// Merge every ring (shared + delivered) into one list ordered by
    /// span start. Call after the workers exited (server shutdown) —
    /// a still-running worker's ring has not been delivered yet.
    pub fn events(&self) -> Vec<Event> {
        let mut out: Vec<Event> = Vec::new();
        out.extend(self.shared.lock().unwrap().events());
        for ring in self.collected.lock().unwrap().iter() {
            out.extend(ring.events());
        }
        out.sort_by(|a, b| a.t_start_us.total_cmp(&b.t_start_us));
        out
    }

    /// Events overwritten across every ring (0 = the trace is complete).
    pub fn dropped(&self) -> u64 {
        self.shared.lock().unwrap().dropped
            + self
                .collected
                .lock()
                .unwrap()
                .iter()
                .map(|r| r.dropped)
                .sum::<u64>()
    }
}

/// One worker's owned event ring. Recording writes the local buffer —
/// no lock, no allocation past the ring itself — and [`Drop`] delivers
/// the ring to the tracer when the worker loop exits.
#[derive(Debug)]
pub struct WorkerTrace {
    tracer: Arc<Tracer>,
    ring: Ring,
    worker: u32,
}

impl WorkerTrace {
    pub fn now_us(&self) -> f64 {
        self.tracer.now_us()
    }

    pub fn us_of(&self, t: Instant) -> f64 {
        self.tracer.us_of(t)
    }

    pub fn worker(&self) -> u32 {
        self.worker
    }

    /// Record into the worker-owned ring (the hot-path `record`).
    pub fn record(&mut self, ev: Event) {
        self.ring.push(ev);
    }
}

impl Drop for WorkerTrace {
    fn drop(&mut self) {
        let ring = std::mem::replace(&mut self.ring, Ring::new(1));
        self.tracer.collected.lock().unwrap().push(ring);
    }
}

// ---------------------------------------------------------------------------
// Perfetto export
// ---------------------------------------------------------------------------

/// Perfetto/`chrome://tracing` track layout (the `pid` of each event):
/// wall-time worker tracks, wall-time request tracks, modeled-sim-time
/// pipeline-stage tracks.
const PID_SERVING: f64 = 0.0;
const PID_REQUESTS: f64 = 1.0;
const PID_STAGES: f64 = 2.0;

fn kind_name(k: EventKind) -> &'static str {
    match k {
        EventKind::Enqueue => "enqueue",
        EventKind::Admit => "queue-wait",
        EventKind::PrefixSplice => "prefix-splice",
        EventKind::PrefillChunk => "prefill-chunk",
        EventKind::DecodeStep => "decode-step",
        EventKind::SpecRound => "spec-round",
        EventKind::Reply => "reply",
        EventKind::Cancel => "cancel",
        EventKind::WorkerStep => "step",
        EventKind::Occupancy => "occupancy",
        EventKind::QueueDepth => "queue depth",
        EventKind::PrefixHitRate => "prefix hit rate",
        EventKind::StageStep => "stage-window",
    }
}

fn meta_event(pid: f64, tid: Option<f64>, key: &str, name: &str) -> Json {
    let mut fields = vec![
        ("ph", s("M")),
        ("pid", num(pid)),
        ("name", s(key)),
        ("args", obj(vec![("name", s(name))])),
    ];
    if let Some(t) = tid {
        fields.push(("tid", num(t)));
    }
    obj(fields)
}

fn span_event(pid: f64, tid: f64, ev: &Event, args: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("ph", s("X")),
        ("pid", num(pid)),
        ("tid", num(tid)),
        ("name", s(kind_name(ev.kind))),
        ("ts", num(ev.t_start_us)),
        ("dur", num((ev.t_end_us - ev.t_start_us).max(0.0))),
        ("args", obj(args)),
    ])
}

fn counter_event(name: &str, ts: f64, series: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("ph", s("C")),
        ("pid", num(PID_SERVING)),
        ("tid", num(0.0)),
        ("name", s(name)),
        ("ts", num(ts)),
        ("args", obj(series)),
    ])
}

/// Stage windows of different workers share the stage-track process;
/// this keys worker × stage into one thread id.
fn stage_tid(worker: u32, stage: u32) -> f64 {
    (worker as f64) * 1000.0 + stage as f64
}

/// Render a merged event list ([`Tracer::events`]) as Chrome/Perfetto
/// trace-event JSON: one wall-time track per worker (step spans +
/// occupancy/queue-depth/prefix counters), one wall-time track per
/// request (queue-wait and chunk spans), and — when a sharded engine
/// recorded stage windows — a modeled-sim-time track per worker ×
/// pipeline stage. Load the written file in <https://ui.perfetto.dev>
/// or `chrome://tracing`.
pub fn perfetto_json(events: &[Event]) -> Json {
    let mut out: Vec<Json> = Vec::new();
    // track metadata: name the processes and every thread we will emit
    let workers: BTreeSet<u32> = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::WorkerStep))
        .map(|e| e.worker)
        .collect();
    let requests: BTreeSet<u64> = events
        .iter()
        .filter(|e| e.request != 0)
        .map(|e| e.request)
        .collect();
    let stages: BTreeSet<(u32, u32)> = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::StageStep))
        .map(|e| (e.worker, e.a))
        .collect();
    out.push(meta_event(PID_SERVING, None, "process_name", "serving (wall µs)"));
    out.push(meta_event(PID_REQUESTS, None, "process_name", "requests (wall µs)"));
    if !stages.is_empty() {
        out.push(meta_event(
            PID_STAGES,
            None,
            "process_name",
            "pipeline stages (modeled sim µs)",
        ));
    }
    for &w in &workers {
        out.push(meta_event(
            PID_SERVING,
            Some(w as f64),
            "thread_name",
            &format!("worker {w}"),
        ));
    }
    for &r in &requests {
        out.push(meta_event(
            PID_REQUESTS,
            Some(r as f64),
            "thread_name",
            &format!("request {r}"),
        ));
    }
    for &(w, st) in &stages {
        out.push(meta_event(
            PID_STAGES,
            Some(stage_tid(w, st)),
            "thread_name",
            &format!("worker {w} stage {st}"),
        ));
    }
    for ev in events {
        let j = match ev.kind {
            EventKind::Enqueue => span_event(
                PID_REQUESTS,
                ev.request as f64,
                ev,
                vec![("prompt_tokens", num(ev.a as f64))],
            ),
            EventKind::Admit => span_event(
                PID_REQUESTS,
                ev.request as f64,
                ev,
                vec![
                    ("worker", num(ev.worker as f64)),
                    ("slot", num(ev.a as f64)),
                    ("prompt_tokens", num(ev.b as f64)),
                ],
            ),
            EventKind::PrefixSplice => span_event(
                PID_REQUESTS,
                ev.request as f64,
                ev,
                vec![("spliced_positions", num(ev.a as f64))],
            ),
            EventKind::PrefillChunk | EventKind::DecodeStep | EventKind::SpecRound => {
                span_event(
                    PID_REQUESTS,
                    ev.request as f64,
                    ev,
                    vec![
                        ("worker", num(ev.worker as f64)),
                        ("positions", num(ev.a as f64)),
                        ("window_pos", num(ev.b as f64)),
                        ("sim_ns", num(ev.sim_ns)),
                    ],
                )
            }
            EventKind::Reply => span_event(
                PID_REQUESTS,
                ev.request as f64,
                ev,
                vec![
                    ("chip_positions", num(ev.a as f64)),
                    ("window_tokens", num(ev.b as f64)),
                    ("sim_ns", num(ev.sim_ns)),
                ],
            ),
            EventKind::Cancel => span_event(
                PID_REQUESTS,
                ev.request as f64,
                ev,
                vec![("positions_fed", num(ev.a as f64))],
            ),
            EventKind::WorkerStep => span_event(
                PID_SERVING,
                ev.worker as f64,
                ev,
                vec![
                    ("lanes", num(ev.a as f64)),
                    ("active_slots", num(ev.b as f64)),
                    ("sim_ns", num(ev.sim_ns)),
                ],
            ),
            EventKind::Occupancy => counter_event(
                &format!("occupancy w{}", ev.worker),
                ev.t_end_us,
                vec![("occupied", num(ev.a as f64))],
            ),
            EventKind::QueueDepth => counter_event(
                "queue depth",
                ev.t_end_us,
                vec![("queued", num(ev.a as f64))],
            ),
            EventKind::PrefixHitRate => counter_event(
                &format!("prefix hit rate w{}", ev.worker),
                ev.t_end_us,
                vec![(
                    "hit_pct",
                    num(if ev.b == 0 {
                        0.0
                    } else {
                        100.0 * ev.a as f64 / ev.b as f64
                    }),
                )],
            ),
            EventKind::StageStep => span_event(
                PID_STAGES,
                stage_tid(ev.worker, ev.a),
                ev,
                vec![
                    ("stage", num(ev.a as f64)),
                    ("microbatch", num(ev.b as f64)),
                    ("sim_ns", num(ev.sim_ns)),
                ],
            ),
        };
        out.push(j);
    }
    obj(vec![
        ("traceEvents", arr(out)),
        ("displayTimeUnit", s("ms")),
    ])
}

// ---------------------------------------------------------------------------
// Per-request breakdown
// ---------------------------------------------------------------------------

/// One request's phase decomposition, reduced from its span tree.
/// `queue_wait_us + prefill_us` is the request's TTFT; `splice_saved_ns`
/// estimates the modeled prefill work the shared-prefix cache answered
/// for free (spliced positions priced at the request's own mean modeled
/// cost per replayed position).
#[derive(Clone, Debug)]
pub struct RequestBreakdown {
    pub request: u64,
    pub worker: u32,
    pub prompt_tokens: u32,
    pub spliced: u32,
    pub queue_wait_us: f64,
    pub prefill_us: f64,
    pub decode_us: f64,
    pub total_us: f64,
    /// Step-boundary chunks the request was fed through.
    pub chunks: u32,
    /// Modeled chip time summed over the request's chunks (ns).
    pub sim_ns: f64,
    pub splice_saved_ns: f64,
    /// `"reply"`, `"cancel"`, or `"open"` (trace ended mid-request —
    /// possible when the ring overwrote its early events).
    pub outcome: &'static str,
}

/// Reduce a merged event list to per-request breakdowns, ordered by
/// request id. Requests without an `Admit` span (overwritten, or
/// cancelled while queued) still appear when any of their events
/// survive.
pub fn breakdowns(events: &[Event]) -> Vec<RequestBreakdown> {
    #[derive(Default)]
    struct Acc {
        admit: Option<Event>,
        first_chunk_end: Option<f64>,
        chunks: u32,
        chip_positions: u64,
        sim_ns: f64,
        spliced: u32,
        end: Option<Event>,
        enqueue_us: Option<f64>,
    }
    let mut by_req: BTreeMap<u64, Acc> = BTreeMap::new();
    for ev in events {
        if ev.request == 0 {
            continue;
        }
        let a = by_req.entry(ev.request).or_default();
        match ev.kind {
            EventKind::Enqueue => a.enqueue_us = Some(ev.t_start_us),
            EventKind::Admit => a.admit = Some(*ev),
            EventKind::PrefixSplice => a.spliced = ev.a,
            EventKind::PrefillChunk | EventKind::DecodeStep | EventKind::SpecRound => {
                a.chunks += 1;
                a.chip_positions += ev.a as u64;
                a.sim_ns += ev.sim_ns;
                let end = a.first_chunk_end.get_or_insert(ev.t_end_us);
                *end = end.min(ev.t_end_us);
            }
            EventKind::Reply | EventKind::Cancel => a.end = Some(*ev),
            _ => {}
        }
    }
    by_req
        .into_iter()
        .map(|(request, a)| {
            let start = a
                .admit
                .map(|e| e.t_start_us)
                .or(a.enqueue_us)
                .unwrap_or(0.0);
            let admit_end = a.admit.map(|e| e.t_end_us).unwrap_or(start);
            let end_us = a.end.map(|e| e.t_end_us);
            let first = a.first_chunk_end;
            let total_us = end_us.map(|e| (e - start).max(0.0)).unwrap_or(0.0);
            RequestBreakdown {
                request,
                worker: a.admit.map(|e| e.worker).unwrap_or(0),
                prompt_tokens: a.admit.map(|e| e.b).unwrap_or(0),
                spliced: a.spliced,
                queue_wait_us: (admit_end - start).max(0.0),
                prefill_us: first.map(|f| (f - admit_end).max(0.0)).unwrap_or(0.0),
                decode_us: match (first, end_us) {
                    (Some(f), Some(e)) => (e - f).max(0.0),
                    _ => 0.0,
                },
                total_us,
                chunks: a.chunks,
                sim_ns: a.sim_ns,
                splice_saved_ns: if a.chip_positions == 0 {
                    0.0
                } else {
                    a.spliced as f64 * a.sim_ns / a.chip_positions as f64
                },
                outcome: match a.end.map(|e| e.kind) {
                    Some(EventKind::Cancel) => "cancel",
                    Some(_) => "reply",
                    None => "open",
                },
            }
        })
        .collect()
}

/// Human-readable per-request breakdown (at most `limit` rows; the rest
/// are summarized in a trailing note). TTFT = queue µs + prefill µs.
pub fn breakdown_table(events: &[Event], limit: usize) -> String {
    let rows = breakdowns(events);
    let mut t = Table::new([
        "req", "worker", "tokens", "spliced", "queue µs", "prefill µs", "decode µs",
        "total µs", "sim µs", "saved µs", "outcome",
    ]);
    for r in rows.iter().take(limit) {
        t.row([
            r.request.to_string(),
            r.worker.to_string(),
            r.prompt_tokens.to_string(),
            r.spliced.to_string(),
            format!("{:.1}", r.queue_wait_us),
            format!("{:.1}", r.prefill_us),
            format!("{:.1}", r.decode_us),
            format!("{:.1}", r.total_us),
            format!("{:.2}", r.sim_ns / 1e3),
            format!("{:.2}", r.splice_saved_ns / 1e3),
            r.outcome.to_string(),
        ]);
    }
    let mut out = t.render();
    if rows.len() > limit {
        out.push_str(&format!("({} more requests not shown)\n", rows.len() - limit));
    }
    out
}

// ---------------------------------------------------------------------------
// Offline decode timeline
// ---------------------------------------------------------------------------

/// Perfetto timeline for an offline `decode` run: one modeled-sim-time
/// track per labelled run (strategy), one span per chip pass, placed by
/// the cumulative critical-path latency of its predecessors. The same
/// trace-event schema as [`perfetto_json`], so the files load the same
/// way.
pub fn decode_timeline_json(runs: &[(String, Vec<Cost>)]) -> Json {
    let mut out: Vec<Json> = vec![meta_event(0.0, None, "process_name", "decode (modeled sim µs)")];
    for (tid, (name, costs)) in runs.iter().enumerate() {
        out.push(meta_event(0.0, Some(tid as f64), "thread_name", name));
        let mut cursor_ns = 0.0f64;
        for (i, c) in costs.iter().enumerate() {
            let dur_ns = c.latency.critical_ns();
            out.push(obj(vec![
                ("ph", s("X")),
                ("pid", num(0.0)),
                ("tid", num(tid as f64)),
                ("name", s("pass")),
                ("ts", num(cursor_ns / 1e3)),
                ("dur", num((dur_ns / 1e3).max(0.0))),
                (
                    "args",
                    obj(vec![
                        ("position", num(i as f64)),
                        ("energy_nj", num(c.energy.total_nj())),
                        ("mha_ns", num(c.latency.mha_ns)),
                    ]),
                ),
            ]));
            cursor_ns += dur_ns;
        }
    }
    obj(vec![
        ("traceEvents", arr(out)),
        ("displayTimeUnit", s("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, request: u64, t0: f64, t1: f64) -> Event {
        Event {
            kind,
            request,
            worker: 0,
            t_start_us: t0,
            t_end_us: t1,
            sim_ns: 0.0,
            a: 0,
            b: 0,
        }
    }

    #[test]
    fn ring_is_bounded_and_overwrites_oldest() {
        let mut r = Ring::new(4);
        for i in 0..10u64 {
            r.push(ev(EventKind::DecodeStep, i, i as f64, i as f64 + 1.0));
        }
        assert_eq!(r.len(), 4, "ring never exceeds its capacity");
        assert_eq!(r.dropped, 6);
        let kept: Vec<u64> = r.events().map(|e| e.request).collect();
        assert_eq!(kept, vec![6, 7, 8, 9], "oldest-first, newest retained");
        // a zero capacity clamps to one instead of dividing by zero
        let mut r = Ring::new(0);
        r.push(ev(EventKind::Reply, 1, 0.0, 0.0));
        r.push(ev(EventKind::Reply, 2, 1.0, 1.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r.dropped, 1);
    }

    #[test]
    fn tracer_ids_and_worker_ring_delivery() {
        let t = Arc::new(Tracer::new(64));
        assert_eq!(t.next_request_id(), 1);
        assert_eq!(t.next_request_id(), 2);
        t.record(ev(EventKind::Enqueue, 1, 5.0, 5.0));
        {
            let mut w = t.worker(3);
            assert_eq!(w.worker(), 3);
            let mut e = ev(EventKind::Reply, 1, 9.0, 9.0);
            e.worker = 3;
            w.record(e);
            // ring not yet delivered: only the shared event is visible
            assert_eq!(t.events().len(), 1);
        }
        // drop delivered the worker ring; merged list is start-ordered
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::Enqueue);
        assert_eq!(evs[1].kind, EventKind::Reply);
        assert_eq!(evs[1].worker, 3);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn perfetto_export_shape_is_valid() {
        let mut events = vec![
            ev(EventKind::Enqueue, 1, 0.0, 0.0),
            ev(EventKind::Admit, 1, 0.0, 10.0),
            ev(EventKind::PrefillChunk, 1, 10.0, 30.0),
            ev(EventKind::DecodeStep, 1, 30.0, 40.0),
            ev(EventKind::Reply, 1, 40.0, 40.0),
        ];
        let mut step = ev(EventKind::WorkerStep, 0, 10.0, 30.0);
        step.a = 4;
        events.push(step);
        let mut occ = ev(EventKind::Occupancy, 0, 30.0, 30.0);
        occ.a = 1;
        occ.b = 8;
        events.push(occ);
        let mut stage = ev(EventKind::StageStep, 0, 2.0, 5.0);
        stage.a = 1;
        stage.b = 0;
        events.push(stage);
        let doc = perfetto_json(&events);
        // reparse of the writer output survives (well-formed JSON)
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let evs = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        let mut spans = 0;
        let mut counters = 0;
        let mut meta = 0;
        for e in evs {
            match e.get("ph").unwrap().as_str().unwrap() {
                "X" => {
                    spans += 1;
                    let dur = e.get("dur").unwrap().as_f64().unwrap();
                    assert!(dur >= 0.0, "negative span duration: {e}");
                    assert!(e.get("ts").is_some() && e.get("name").is_some());
                }
                "C" => counters += 1,
                "M" => meta += 1,
                other => panic!("unexpected phase {other}"),
            }
        }
        assert_eq!(spans, 7, "every non-counter event becomes a span");
        assert_eq!(counters, 1);
        // process names for all three pids + worker/request/stage threads
        assert!(meta >= 5, "track metadata missing: {meta}");
    }

    #[test]
    fn breakdown_decomposes_ttft() {
        let mut splice = ev(EventKind::PrefixSplice, 1, 105.0, 105.0);
        splice.a = 2;
        let mut admit = ev(EventKind::Admit, 1, 100.0, 150.0);
        admit.b = 6;
        admit.worker = 2;
        let mut chunk = ev(EventKind::PrefillChunk, 1, 150.0, 250.0);
        chunk.a = 3;
        chunk.sim_ns = 3000.0;
        let mut step = ev(EventKind::DecodeStep, 1, 250.0, 400.0);
        step.a = 1;
        step.sim_ns = 1000.0;
        let mut reply = ev(EventKind::Reply, 1, 400.0, 400.0);
        reply.sim_ns = 4000.0;
        let events = vec![admit, splice, chunk, step, reply];
        let rows = breakdowns(&events);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.request, 1);
        assert_eq!(r.worker, 2);
        assert_eq!(r.prompt_tokens, 6);
        assert_eq!(r.spliced, 2);
        assert!((r.queue_wait_us - 50.0).abs() < 1e-9);
        assert!((r.prefill_us - 100.0).abs() < 1e-9);
        assert!((r.decode_us - 150.0).abs() < 1e-9);
        assert!((r.total_us - 300.0).abs() < 1e-9);
        assert_eq!(r.chunks, 2);
        assert!((r.sim_ns - 4000.0).abs() < 1e-9);
        // 2 spliced positions at the request's 1000 ns/position mean
        assert!((r.splice_saved_ns - 2000.0).abs() < 1e-9);
        assert_eq!(r.outcome, "reply");
        let table = breakdown_table(&events, 32);
        assert!(table.contains("queue µs"));
        assert!(table.contains("reply"));
        // the cap note appears only past the limit
        let capped = breakdown_table(&events, 0);
        assert!(capped.contains("1 more requests not shown"));
    }

    #[test]
    fn decode_timeline_places_passes_back_to_back() {
        let mut c1 = Cost::default();
        c1.latency.analog_ns = 1000.0;
        let mut c2 = Cost::default();
        c2.latency.analog_ns = 2000.0;
        let doc = decode_timeline_json(&[("dense".to_string(), vec![c1, c2])]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(spans[0].get("dur").unwrap().as_f64(), Some(1.0));
        assert_eq!(spans[1].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(spans[1].get("dur").unwrap().as_f64(), Some(2.0));
    }
}
