//! Serving metrics: request latency histogram, batch-size distribution,
//! throughput counters. Shared across the server worker and callers via
//! a mutex (low-rate metadata updates only — never on the tensor path).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

/// Accumulator state. Everything here is **bounded**: the histograms
/// are fixed log-bucket arrays (`util::stats::Histogram`, constant
/// memory for any sample count), the scalar counters are scalars, and
/// the only vectors are indexed by worker / pipeline-stage count —
/// configuration-sized, never per-sample. A serve-load run of any
/// length holds constant metrics memory (ISSUE 9 satellite; the raw
/// per-sample `Vec<f64>`/`Vec<usize>` storage this replaced grew
/// without bound).
#[derive(Debug, Default)]
struct Inner {
    latency_us: Histogram,
    /// Summed completion-group sizes (mean batch = sum / batches) —
    /// a counter, not the raw per-batch size list.
    batch_size_sum: u64,
    requests: u64,
    batches: u64,
    errors: u64,
    /// Tokens processed by the CIM-sim backend.
    sim_tokens: u64,
    /// Summed *modeled* chip latency (ns) and energy (nJ) of those tokens.
    sim_latency_ns: f64,
    sim_energy_nj: f64,
    /// Continuous batching: per-step occupied-slot samples.
    occ_steps: u64,
    occ_sum: u64,
    occ_peak: usize,
    /// Slot capacity of the batched engine (latest reported).
    occ_capacity: usize,
    /// Time-to-first-token per request (µs): submission until the first
    /// position's logits exist — the prefill phase, what chunked prompt
    /// ingestion optimizes.
    ttft_us: Histogram,
    /// Inter-token latency per request (µs): mean wall time per position
    /// *after* the first chunk — the steady decode cadence.
    inter_token_us: Histogram,
    /// Chunked prefill: replays that carried more than one position.
    prefill_chunks: u64,
    /// Positions ingested through those multi-position replays.
    prefill_positions: u64,
    /// Speculative decoding: verify rounds in which the draft proposed.
    spec_rounds: u64,
    /// Draft tokens proposed across those rounds.
    spec_proposed: u64,
    /// Draft tokens accepted (each equal to the served window's actual
    /// next token).
    spec_accepted: u64,
    /// Shared-prefix KV cache (DESIGN.md §6g): admission-time lookups.
    prefix_lookups: u64,
    /// Lookups that spliced at least one cached position.
    prefix_hits: u64,
    /// Prompt positions answered from the cache instead of prefilled.
    prefix_saved_positions: u64,
    /// Requests abandoned by their client (dropped reply channel) —
    /// slots released early instead of decoding for nobody.
    cancellations: u64,
    /// Multi-worker serving: per-worker occupancy accumulators,
    /// `(steps, occupied-slot sum, peak, capacity)` indexed by worker.
    worker_occ: Vec<(u64, u64, usize, usize)>,
    /// Layer-sharded pipeline (`sim::shard`): sharded steps recorded.
    pipe_steps: u64,
    /// Modeled busy time per pipeline stage (ns), summed over steps —
    /// the per-stage counters behind [`Snapshot::stage_occupancy`].
    pipe_stage_busy_ns: Vec<f64>,
    /// Summed modeled step makespans (ns).
    pipe_span_ns: f64,
    /// Summed modeled inter-chip activation-transfer latency (ns).
    pipe_transfer_ns: f64,
    /// Summed modeled 1-chip serial baseline of the same work (ns).
    pipe_serial_ns: f64,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    /// PJRT backend: mean requests per executed batch. CIM-sim backend:
    /// mean requests per *completion group* (requests finishing in the
    /// same token step) — ragged windows finish at different steps, so
    /// this can read 1.0 while the chip ran fully batched; use
    /// [`Snapshot::occupancy_mean`] to judge continuous-batching
    /// efficiency.
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub throughput_rps: f64,
    /// CIM-sim backend: tokens decoded/scored on the emulated chip.
    pub sim_tokens: u64,
    /// CIM-sim backend: mean modeled chip latency per token (ns).
    pub sim_token_latency_ns: f64,
    /// CIM-sim backend: summed modeled energy (nJ).
    pub sim_energy_nj: f64,
    /// CIM-sim backend: host wall-clock token throughput (tokens/sec
    /// since server start).
    pub sim_tokens_per_sec: f64,
    /// Continuous batching: mean occupied slots per token step.
    pub occupancy_mean: f64,
    /// Continuous batching: peak occupied slots over any step.
    pub occupancy_peak: usize,
    /// Continuous batching: slot capacity of the batched engine.
    pub slot_capacity: usize,
    /// Time-to-first-token percentiles (µs; 0.0 until a request with
    /// recorded phase timing completes).
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    /// Inter-token (post-first-chunk) latency percentiles (µs).
    pub inter_token_p50_us: f64,
    pub inter_token_p99_us: f64,
    /// Chunked prefill: multi-position replays executed, and the
    /// positions they carried (mean chunk = positions / chunks).
    pub prefill_chunks: u64,
    pub prefill_positions: u64,
    /// Speculative decoding: verify rounds with at least one proposal.
    pub spec_rounds: u64,
    /// Accepted / proposed draft tokens over all rounds (0.0 until a
    /// round with proposals completes). This is the draft-quality dial:
    /// chunk width per verify round is `accepted + 1`.
    pub spec_acceptance_rate: f64,
    /// Mean positions advanced per verify round (`accepted + 1` per
    /// round; plain decode is 1.0, anything above is the speculative
    /// win). 0.0 until a round completes.
    pub spec_tokens_per_round: f64,
    /// Shared-prefix KV cache: admission-time lookups, lookups that
    /// spliced cached positions, and the hit ratio (0.0 until a lookup
    /// happens).
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_hit_rate: f64,
    /// Prompt positions answered from the prefix cache — prefill work
    /// the chip never had to replay.
    pub prefix_positions_saved: u64,
    /// Requests whose client vanished (dropped reply channel) before
    /// the reply landed; their slots were released early.
    pub cancellations: u64,
    /// Multi-worker serving: worker threads that reported occupancy.
    pub workers: usize,
    /// Mean occupied slots per step, per worker (empty until a worker
    /// reports) — the load-balance view the aggregate mean hides.
    pub worker_occupancy: Vec<f64>,
    /// Layer-sharded pipeline: stage count of the backing engine (0
    /// when serving unsharded).
    pub shard_stages: usize,
    /// Sharded steps recorded.
    pub pipeline_steps: u64,
    /// Per-stage occupancy: fraction of the accumulated modeled span
    /// each stage chip spent busy (empty when unsharded).
    pub stage_occupancy: Vec<f64>,
    /// Idle fraction of the stage-time grid — the pipeline-bubble share
    /// (0.0 until a sharded step is recorded).
    pub pipeline_bubble_fraction: f64,
    /// Modeled throughput gain of the pipeline over one chip running
    /// the same steps serially (0.0 until a sharded step is recorded).
    pub pipeline_speedup: f64,
    /// Summed modeled inter-chip transfer latency (ns).
    pub pipeline_transfer_ns: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            started: Some(Instant::now()),
        }
    }

    pub fn record_batch(&self, batch_size: usize, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += batch_size as u64;
        g.batch_size_sum += batch_size as u64;
        for _ in 0..batch_size {
            g.latency_us.record(latency_us);
        }
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record one continuous-batching completion group: requests that
    /// finished in the same token step, each with its OWN end-to-end
    /// latency (unlike [`Metrics::record_batch`]'s shared batch latency
    /// — under continuous batching, same-step finishers may have been
    /// admitted hundreds of steps apart, and averaging them would hide
    /// tail latency from the percentiles).
    pub fn record_completions(&self, latencies_us: &[f64]) {
        if latencies_us.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += latencies_us.len() as u64;
        g.batch_size_sum += latencies_us.len() as u64;
        for &us in latencies_us {
            g.latency_us.record(us);
        }
    }

    /// Account tokens processed on the CIM-sim backend together with
    /// their *modeled* (simulated-chip) latency and energy.
    pub fn record_sim_tokens(&self, tokens: usize, latency_ns: f64, energy_nj: f64) {
        let mut g = self.inner.lock().unwrap();
        g.sim_tokens += tokens as u64;
        g.sim_latency_ns += latency_ns;
        g.sim_energy_nj += energy_nj;
    }

    /// Record one request's phase split: `ttft_us` is submission →
    /// first logits (queue wait + prefill — the latency chunked prefill
    /// attacks); `inter_token_us`, when the request spanned more than
    /// its first chunk, is the mean wall time per subsequent position
    /// (the decode cadence). Keeping the two apart is what makes a
    /// serving report honest: a chunked server can cut TTFT by an order
    /// of magnitude while the inter-token cadence is unchanged, and a
    /// single blended latency number would show neither.
    pub fn record_request_timing(&self, ttft_us: f64, inter_token_us: Option<f64>) {
        let mut g = self.inner.lock().unwrap();
        g.ttft_us.record(ttft_us);
        if let Some(us) = inter_token_us {
            g.inter_token_us.record(us);
        }
    }

    /// Account one multi-position prefill replay of `positions` prompt
    /// positions (single-position steps are ordinary decode lanes and
    /// are not counted here).
    pub fn record_prefill_chunk(&self, positions: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefill_chunks += 1;
        g.prefill_positions += positions as u64;
    }

    /// Record one speculative verify round: the draft `proposed` tokens
    /// for a served window and `accepted` of them matched the window's
    /// actual continuation (so the round's verify chunk advanced
    /// `accepted + 1` positions). Rounds without proposals (K clipped
    /// to 0 at a window tail) are not recorded — they are ordinary
    /// decode steps.
    pub fn record_speculation(&self, proposed: usize, accepted: usize) {
        debug_assert!(accepted <= proposed);
        let mut g = self.inner.lock().unwrap();
        g.spec_rounds += 1;
        g.spec_proposed += proposed as u64;
        g.spec_accepted += accepted as u64;
    }

    /// Sample the continuous-batching occupancy after one token step:
    /// `active` slots held in-flight sequences out of `capacity`.
    pub fn record_occupancy(&self, active: usize, capacity: usize) {
        let mut g = self.inner.lock().unwrap();
        g.occ_steps += 1;
        g.occ_sum += active as u64;
        g.occ_peak = g.occ_peak.max(active);
        g.occ_capacity = capacity;
    }

    /// Sample one worker's occupancy after one of its token steps —
    /// feeds both the aggregate counters ([`Metrics::record_occupancy`]
    /// semantics) and the per-worker means the dispatcher's load
    /// balance is judged by.
    pub fn record_worker_occupancy(&self, worker: usize, active: usize, capacity: usize) {
        let mut g = self.inner.lock().unwrap();
        g.occ_steps += 1;
        g.occ_sum += active as u64;
        g.occ_peak = g.occ_peak.max(active);
        g.occ_capacity = capacity;
        if g.worker_occ.len() <= worker {
            g.worker_occ.resize(worker + 1, (0, 0, 0, 0));
        }
        let w = &mut g.worker_occ[worker];
        w.0 += 1;
        w.1 += active as u64;
        w.2 = w.2.max(active);
        w.3 = capacity;
    }

    /// Record one shared-prefix cache lookup at admission: `saved` is
    /// the number of prompt positions spliced from the cache (0 = miss).
    pub fn record_prefix_lookup(&self, saved: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefix_lookups += 1;
        if saved > 0 {
            g.prefix_hits += 1;
            g.prefix_saved_positions += saved as u64;
        }
    }

    /// Record one abandoned request: the client dropped its reply
    /// channel, so the request's slot was released before (or its reply
    /// discarded after) the window finished.
    pub fn record_cancellation(&self) {
        self.inner.lock().unwrap().cancellations += 1;
    }

    /// Account one (or a window of) layer-sharded pipeline step(s):
    /// modeled busy time per stage, total makespan, inter-chip transfer
    /// latency and the 1-chip serial baseline — the aggregates a
    /// [`PipelineStats`](crate::sim::PipelineStats) window carries.
    pub fn record_pipeline(
        &self,
        steps: u64,
        stage_busy_ns: &[f64],
        span_ns: f64,
        transfer_ns: f64,
        serial_ns: f64,
    ) {
        if steps == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.pipe_steps += steps;
        if g.pipe_stage_busy_ns.len() < stage_busy_ns.len() {
            g.pipe_stage_busy_ns.resize(stage_busy_ns.len(), 0.0);
        }
        for (acc, b) in g.pipe_stage_busy_ns.iter_mut().zip(stage_busy_ns) {
            *acc += b;
        }
        g.pipe_span_ns += span_ns;
        g.pipe_transfer_ns += transfer_ns;
        g.pipe_serial_ns += serial_ns;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        // Rates need a start time AND at least one counted event AND
        // measurable elapsed time; anything else reports 0.0 — the same
        // "no samples yet" convention as the percentile guards below. A
        // default-constructed Metrics (`started: None`) must not invent
        // a phantom rate, and a snapshot taken nanoseconds after start
        // must not divide by ~0 into an absurd one.
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64());
        let rate = |count: u64| match elapsed {
            Some(e) if count > 0 && e > 0.0 => count as f64 / e.max(1e-9),
            _ => 0.0,
        };
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            mean_batch: if g.batches == 0 {
                0.0
            } else {
                g.batch_size_sum as f64 / g.batches as f64
            },
            latency_p50_us: if g.latency_us.is_empty() {
                0.0
            } else {
                g.latency_us.p50()
            },
            latency_p99_us: if g.latency_us.is_empty() {
                0.0
            } else {
                g.latency_us.p99()
            },
            throughput_rps: rate(g.requests),
            sim_tokens: g.sim_tokens,
            sim_token_latency_ns: if g.sim_tokens == 0 {
                0.0
            } else {
                g.sim_latency_ns / g.sim_tokens as f64
            },
            sim_energy_nj: g.sim_energy_nj,
            sim_tokens_per_sec: rate(g.sim_tokens),
            occupancy_mean: if g.occ_steps == 0 {
                0.0
            } else {
                g.occ_sum as f64 / g.occ_steps as f64
            },
            occupancy_peak: g.occ_peak,
            slot_capacity: g.occ_capacity,
            ttft_p50_us: if g.ttft_us.is_empty() { 0.0 } else { g.ttft_us.p50() },
            ttft_p99_us: if g.ttft_us.is_empty() { 0.0 } else { g.ttft_us.p99() },
            inter_token_p50_us: if g.inter_token_us.is_empty() {
                0.0
            } else {
                g.inter_token_us.p50()
            },
            inter_token_p99_us: if g.inter_token_us.is_empty() {
                0.0
            } else {
                g.inter_token_us.p99()
            },
            prefill_chunks: g.prefill_chunks,
            prefill_positions: g.prefill_positions,
            spec_rounds: g.spec_rounds,
            spec_acceptance_rate: if g.spec_proposed == 0 {
                0.0
            } else {
                g.spec_accepted as f64 / g.spec_proposed as f64
            },
            spec_tokens_per_round: if g.spec_rounds == 0 {
                0.0
            } else {
                (g.spec_accepted + g.spec_rounds) as f64 / g.spec_rounds as f64
            },
            prefix_lookups: g.prefix_lookups,
            prefix_hits: g.prefix_hits,
            prefix_hit_rate: if g.prefix_lookups == 0 {
                0.0
            } else {
                g.prefix_hits as f64 / g.prefix_lookups as f64
            },
            prefix_positions_saved: g.prefix_saved_positions,
            cancellations: g.cancellations,
            workers: g.worker_occ.len(),
            worker_occupancy: g
                .worker_occ
                .iter()
                .map(|&(steps, sum, _, _)| {
                    if steps == 0 { 0.0 } else { sum as f64 / steps as f64 }
                })
                .collect(),
            shard_stages: g.pipe_stage_busy_ns.len(),
            pipeline_steps: g.pipe_steps,
            stage_occupancy: if g.pipe_span_ns > 0.0 {
                g.pipe_stage_busy_ns
                    .iter()
                    .map(|b| (b / g.pipe_span_ns).min(1.0))
                    .collect()
            } else {
                vec![0.0; g.pipe_stage_busy_ns.len()]
            },
            pipeline_bubble_fraction: {
                let stages = g.pipe_stage_busy_ns.len();
                if stages == 0 || g.pipe_span_ns <= 0.0 {
                    0.0
                } else {
                    let busy: f64 = g.pipe_stage_busy_ns.iter().sum();
                    (1.0 - busy / (stages as f64 * g.pipe_span_ns)).max(0.0)
                }
            },
            pipeline_speedup: if g.pipe_span_ns > 0.0 {
                g.pipe_serial_ns / g.pipe_span_ns
            } else {
                0.0
            },
            pipeline_transfer_ns: g.pipe_transfer_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(4, 100.0);
        m.record_batch(2, 200.0);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.latency_p50_us >= 100.0);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn long_streams_stay_bounded_and_accurate() {
        // ISSUE 9 satellite: metrics hold constant memory for any
        // sample count — the histograms are fixed arrays and batch
        // sizes are a running sum, so 10^5 completion groups cost the
        // same bytes as one. p50/p99 stay within one log-bucket width
        // (~10%) of the exact answer; mean_batch is exact.
        let m = Metrics::new();
        for i in 0..100_000u64 {
            let us = 100.0 + (i % 1000) as f64;
            m.record_completions(&[us, us * 2.0]);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 200_000);
        assert_eq!(s.batches, 100_000);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        // exact p50 of the {u, 2u} mix (u uniform in [100,1100)) is
        // ~600us; one bucket of slack on either side
        assert!(s.latency_p50_us > 400.0 && s.latency_p50_us < 900.0);
        assert!(s.latency_p99_us > 1_800.0 && s.latency_p99_us <= 2_198.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_p50_us, 0.0);
        assert_eq!(s.sim_tokens, 0);
        assert_eq!(s.sim_token_latency_ns, 0.0);
    }

    #[test]
    fn completion_groups_keep_per_request_latency() {
        let m = Metrics::new();
        m.record_completions(&[100.0, 10_000.0]);
        m.record_completions(&[]); // no-op
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        // both individual latencies survive into the histogram
        assert!(s.latency_p50_us <= 10_000.0 && s.latency_p50_us >= 100.0);
        assert!(s.latency_p99_us >= 9_000.0, "tail hidden: {}", s.latency_p99_us);
    }

    #[test]
    fn occupancy_accounting() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.occupancy_mean, 0.0);
        assert_eq!(s.occupancy_peak, 0);
        m.record_occupancy(1, 8);
        m.record_occupancy(5, 8);
        m.record_occupancy(3, 8);
        let s = m.snapshot();
        assert!((s.occupancy_mean - 3.0).abs() < 1e-9);
        assert_eq!(s.occupancy_peak, 5);
        assert_eq!(s.slot_capacity, 8);
    }

    #[test]
    fn occupancy_capacity_zero_and_degenerate_samples() {
        // ISSUE-7 satellite: record_occupancy edge cases. A capacity-0
        // report (an engine with no slots cannot exist, but a scraper
        // must survive a misconfigured reporter) keeps every derived
        // value finite and sane; all-zero samples stay zero.
        let m = Metrics::new();
        m.record_occupancy(0, 0);
        m.record_occupancy(0, 0);
        let s = m.snapshot();
        assert_eq!(s.occupancy_mean, 0.0);
        assert_eq!(s.occupancy_peak, 0);
        assert_eq!(s.slot_capacity, 0);
        assert!(s.occupancy_mean.is_finite());
        // capacity reported later wins (latest engine shape)
        m.record_occupancy(1, 1);
        let s = m.snapshot();
        assert_eq!(s.slot_capacity, 1);
        assert!((s.occupancy_mean - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.occupancy_peak, 1);
    }

    #[test]
    fn pipeline_accounting_per_stage() {
        // per-stage counters: two recorded windows accumulate busy time
        // by stage index, and the derived occupancy/bubble/speedup use
        // the summed span
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.shard_stages, 0);
        assert_eq!(s.pipeline_steps, 0);
        assert!(s.stage_occupancy.is_empty());
        assert_eq!(s.pipeline_bubble_fraction, 0.0);
        assert_eq!(s.pipeline_speedup, 0.0);
        // window 1: 2 stages, span 100ns, busy [100, 50], serial 150
        m.record_pipeline(1, &[100.0, 50.0], 100.0, 4.0, 150.0);
        // window 2: same shape
        m.record_pipeline(2, &[100.0, 50.0], 100.0, 4.0, 150.0);
        let s = m.snapshot();
        assert_eq!(s.shard_stages, 2);
        assert_eq!(s.pipeline_steps, 3);
        assert_eq!(s.stage_occupancy.len(), 2);
        assert!((s.stage_occupancy[0] - 1.0).abs() < 1e-9);
        assert!((s.stage_occupancy[1] - 0.5).abs() < 1e-9);
        // busy 300 of 2*200 stage-time → bubble 0.25
        assert!((s.pipeline_bubble_fraction - 0.25).abs() < 1e-9);
        assert!((s.pipeline_speedup - 1.5).abs() < 1e-9);
        assert!((s.pipeline_transfer_ns - 8.0).abs() < 1e-9);
        // a zero-step report is a no-op, not a poisoned window
        m.record_pipeline(0, &[9999.0], 9999.0, 9999.0, 9999.0);
        let s2 = m.snapshot();
        assert_eq!(s2.pipeline_steps, 3);
        assert_eq!(s2.shard_stages, 2);
    }

    #[test]
    fn pipeline_single_stage_has_no_bubbles() {
        // a 1-stage "pipeline" (shards=1) is the serial engine: fully
        // occupied, zero bubble, speedup 1.0
        let m = Metrics::new();
        m.record_pipeline(4, &[400.0], 400.0, 0.0, 400.0);
        let s = m.snapshot();
        assert_eq!(s.shard_stages, 1);
        assert_eq!(s.stage_occupancy, vec![1.0]);
        assert_eq!(s.pipeline_bubble_fraction, 0.0);
        assert!((s.pipeline_speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn request_phase_split_and_prefill_accounting() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.ttft_p50_us, 0.0);
        assert_eq!(s.inter_token_p50_us, 0.0);
        assert_eq!(s.prefill_chunks, 0);
        // a fast-prefill request and a slow one; one had no decode phase
        m.record_request_timing(120.0, Some(40.0));
        m.record_request_timing(9_000.0, None);
        m.record_prefill_chunk(8);
        m.record_prefill_chunk(4);
        let s = m.snapshot();
        assert!(s.ttft_p50_us >= 120.0 && s.ttft_p50_us <= 9_000.0);
        assert!(s.ttft_p99_us >= 8_000.0, "tail hidden: {}", s.ttft_p99_us);
        assert!(s.inter_token_p50_us >= 40.0);
        assert_eq!(s.prefill_chunks, 2);
        assert_eq!(s.prefill_positions, 12);
    }

    #[test]
    fn speculation_accounting() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.spec_rounds, 0);
        assert_eq!(s.spec_acceptance_rate, 0.0);
        assert_eq!(s.spec_tokens_per_round, 0.0);
        // round 1: 4 proposed, 3 accepted (advanced 4 positions);
        // round 2: 4 proposed, 0 accepted (advanced 1 — pure decode pace)
        m.record_speculation(4, 3);
        m.record_speculation(4, 0);
        let s = m.snapshot();
        assert_eq!(s.spec_rounds, 2);
        assert!((s.spec_acceptance_rate - 3.0 / 8.0).abs() < 1e-12);
        assert!((s.spec_tokens_per_round - 2.5).abs() < 1e-12);
        // a round whose every proposal landed
        m.record_speculation(2, 2);
        let s = m.snapshot();
        assert_eq!(s.spec_rounds, 3);
        assert!((s.spec_acceptance_rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_with_no_samples_and_one_sample() {
        // the untested edge cases: every percentile must be 0.0 with no
        // samples (`util::stats::percentile` now reports 0.0 on empty
        // input itself; the is_empty guards keep the convention local
        // and explicit), and a single sample must be both its own p50
        // and p99
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 0.0);
        assert_eq!(s.latency_p99_us, 0.0);
        assert_eq!(s.ttft_p50_us, 0.0);
        assert_eq!(s.ttft_p99_us, 0.0);
        assert_eq!(s.inter_token_p50_us, 0.0);
        assert_eq!(s.inter_token_p99_us, 0.0);
        m.record_request_timing(250.0, None);
        m.record_completions(&[500.0]);
        let s = m.snapshot();
        assert_eq!(s.ttft_p50_us, 250.0);
        assert_eq!(s.ttft_p99_us, 250.0);
        assert_eq!(s.latency_p50_us, 500.0);
        assert_eq!(s.latency_p99_us, 500.0);
        // inter-token still has no samples
        assert_eq!(s.inter_token_p50_us, 0.0);
        m.record_request_timing(100.0, Some(40.0));
        let s = m.snapshot();
        assert_eq!(s.inter_token_p50_us, 40.0);
        assert_eq!(s.inter_token_p99_us, 40.0);
    }

    #[test]
    fn prefix_cache_accounting() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.prefix_lookups, 0);
        assert_eq!(s.prefix_hit_rate, 0.0, "no lookups must not divide by zero");
        assert_eq!(s.prefix_positions_saved, 0);
        // two misses, two hits saving 12 + 4 positions
        m.record_prefix_lookup(0);
        m.record_prefix_lookup(12);
        m.record_prefix_lookup(0);
        m.record_prefix_lookup(4);
        let s = m.snapshot();
        assert_eq!(s.prefix_lookups, 4);
        assert_eq!(s.prefix_hits, 2);
        assert!((s.prefix_hit_rate - 0.5).abs() < 1e-12);
        assert_eq!(s.prefix_positions_saved, 16);
    }

    #[test]
    fn cancellation_accounting() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().cancellations, 0);
        m.record_cancellation();
        m.record_cancellation();
        assert_eq!(m.snapshot().cancellations, 2);
    }

    #[test]
    fn per_worker_occupancy_feeds_both_views() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.workers, 0);
        assert!(s.worker_occupancy.is_empty());
        // worker 1 reports before worker 0 ever steps (sparse indices
        // must not panic); aggregates see every sample
        m.record_worker_occupancy(1, 4, 8);
        m.record_worker_occupancy(1, 2, 8);
        m.record_worker_occupancy(0, 3, 8);
        let s = m.snapshot();
        assert_eq!(s.workers, 2);
        assert!((s.worker_occupancy[0] - 3.0).abs() < 1e-12);
        assert!((s.worker_occupancy[1] - 3.0).abs() < 1e-12);
        assert!((s.occupancy_mean - 3.0).abs() < 1e-12);
        assert_eq!(s.occupancy_peak, 4);
        assert_eq!(s.slot_capacity, 8);
        // a worker that never stepped reads 0.0, not NaN
        m.record_worker_occupancy(3, 1, 8);
        let s = m.snapshot();
        assert_eq!(s.workers, 4);
        assert_eq!(s.worker_occupancy[2], 0.0);
    }

    #[test]
    fn sim_token_accounting() {
        let m = Metrics::new();
        m.record_sim_tokens(32, 3200.0, 640.0);
        m.record_sim_tokens(32, 6400.0, 640.0);
        let s = m.snapshot();
        assert_eq!(s.sim_tokens, 64);
        assert!((s.sim_token_latency_ns - 150.0).abs() < 1e-9);
        assert!((s.sim_energy_nj - 1280.0).abs() < 1e-9);
    }

    #[test]
    fn rates_are_zero_without_samples() {
        // zero counted events must read as rate 0.0, not NaN/inf or a
        // phantom rate derived from elapsed time alone — same "no
        // samples" convention as the percentile guards
        let s = Metrics::new().snapshot();
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.sim_tokens_per_sec, 0.0);
    }

    #[test]
    fn rates_are_zero_without_a_start_time() {
        // a default-constructed Metrics has no start instant; recording
        // events must still never invent a rate from the unwrap_or
        // placeholder elapsed the old code divided by
        let m = Metrics::default();
        m.record_batch(4, 100.0);
        m.record_sim_tokens(64, 6400.0, 640.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.sim_tokens, 64);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.sim_tokens_per_sec, 0.0);
    }

    #[test]
    fn rates_are_finite_and_positive_with_samples() {
        // the instant-after-start snapshot: elapsed can be arbitrarily
        // small but the clamp keeps the rate finite
        let m = Metrics::new();
        m.record_batch(2, 50.0);
        m.record_sim_tokens(16, 1600.0, 320.0);
        let s = m.snapshot();
        assert!(s.throughput_rps.is_finite() && s.throughput_rps > 0.0);
        assert!(s.sim_tokens_per_sec.is_finite() && s.sim_tokens_per_sec > 0.0);
    }
}
