//! Serving metrics: request latency histogram, batch-size distribution,
//! throughput counters. Shared across the server worker and callers via
//! a mutex (low-rate metadata updates only — never on the tensor path).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

#[derive(Debug, Default)]
struct Inner {
    latency_us: Histogram,
    batch_sizes: Vec<usize>,
    requests: u64,
    batches: u64,
    errors: u64,
    /// Tokens processed by the CIM-sim backend.
    sim_tokens: u64,
    /// Summed *modeled* chip latency (ns) and energy (nJ) of those tokens.
    sim_latency_ns: f64,
    sim_energy_nj: f64,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_batch: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub throughput_rps: f64,
    /// CIM-sim backend: tokens decoded/scored on the emulated chip.
    pub sim_tokens: u64,
    /// CIM-sim backend: mean modeled chip latency per token (ns).
    pub sim_token_latency_ns: f64,
    /// CIM-sim backend: summed modeled energy (nJ).
    pub sim_energy_nj: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            started: Some(Instant::now()),
        }
    }

    pub fn record_batch(&self, batch_size: usize, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += batch_size as u64;
        g.batch_sizes.push(batch_size);
        for _ in 0..batch_size {
            g.latency_us.record(latency_us);
        }
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Account tokens processed on the CIM-sim backend together with
    /// their *modeled* (simulated-chip) latency and energy.
    pub fn record_sim_tokens(&self, tokens: usize, latency_ns: f64, energy_nj: f64) {
        let mut g = self.inner.lock().unwrap();
        g.sim_tokens += tokens as u64;
        g.sim_latency_ns += latency_ns;
        g.sim_energy_nj += energy_nj;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = self
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(1.0)
            .max(1e-9);
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
            },
            latency_p50_us: if g.latency_us.is_empty() {
                0.0
            } else {
                g.latency_us.p50()
            },
            latency_p99_us: if g.latency_us.is_empty() {
                0.0
            } else {
                g.latency_us.p99()
            },
            throughput_rps: g.requests as f64 / elapsed,
            sim_tokens: g.sim_tokens,
            sim_token_latency_ns: if g.sim_tokens == 0 {
                0.0
            } else {
                g.sim_latency_ns / g.sim_tokens as f64
            },
            sim_energy_nj: g.sim_energy_nj,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(4, 100.0);
        m.record_batch(2, 200.0);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.latency_p50_us >= 100.0);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_p50_us, 0.0);
        assert_eq!(s.sim_tokens, 0);
        assert_eq!(s.sim_token_latency_ns, 0.0);
    }

    #[test]
    fn sim_token_accounting() {
        let m = Metrics::new();
        m.record_sim_tokens(32, 3200.0, 640.0);
        m.record_sim_tokens(32, 6400.0, 640.0);
        let s = m.snapshot();
        assert_eq!(s.sim_tokens, 64);
        assert!((s.sim_token_latency_ns - 150.0).abs() < 1e-9);
        assert!((s.sim_energy_nj - 1280.0).abs() < 1e-9);
    }
}
