//! Threaded batching inference server — the L3 request loop.
//!
//! Architecture (tokio-free; DESIGN.md §1): callers submit token
//! sequences through a channel; a dedicated worker thread owns the PJRT
//! [`Runtime`], batches requests (`batching::next_batch`), pads each
//! batch to the nearest compiled batch bucket of the `tiny_lm_b{N}`
//! artifacts, executes, splits the logits and answers each caller
//! through its response channel. Python is never involved.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batching::{next_batch, pick_bucket, BatchPolicy};
use super::metrics::Metrics;
use crate::runtime::{literal_i32, Runtime};
use crate::util::json::Json;

/// One inference request: fixed-length token window (the tiny-LM
/// artifact's seq) answered with per-position logits.
struct Request {
    tokens: Vec<i32>,
    resp: Sender<Result<Vec<f32>>>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            policy: BatchPolicy::default(),
        }
    }
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub seq: usize,
    pub vocab: usize,
}

impl InferenceServer {
    /// Start the worker thread (loads + compiles artifacts eagerly).
    ///
    /// The PJRT client is not `Send`, so the [`Runtime`] is constructed
    /// *inside* the worker thread; readiness (or the startup error) is
    /// reported back through a one-shot channel.
    pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
        let metrics = Arc::new(Metrics::new());
        let metrics_w = metrics.clone();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize)>>();
        let policy = cfg.policy.clone();
        let dir = cfg.artifacts_dir.clone();
        let worker = std::thread::spawn(move || {
            // --- startup: build runtime + discover tiny_lm buckets ---
            let setup = (|| -> Result<(Runtime, Vec<(usize, String, usize, usize)>)> {
                let mut runtime = Runtime::new(&dir)?;
                let mut buckets: Vec<(usize, String, usize, usize)> = Vec::new();
                for a in &runtime.manifest().artifacts {
                    if a.meta.get("kind").and_then(Json::as_str) == Some("tiny_lm") {
                        let batch = a
                            .meta
                            .get("batch")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("tiny_lm artifact missing batch"))?;
                        let seq = a.meta.get("seq").and_then(Json::as_usize).unwrap_or(0);
                        let vocab =
                            a.meta.get("vocab").and_then(Json::as_usize).unwrap_or(0);
                        buckets.push((batch, a.name.clone(), seq, vocab));
                    }
                }
                if buckets.is_empty() {
                    bail!("no tiny_lm artifacts in manifest — run `make artifacts`");
                }
                buckets.sort();
                // eager compile so first-request latency is steady-state
                for (_, name, _, _) in &buckets {
                    runtime.load(name).context("precompiling artifact")?;
                }
                Ok((runtime, buckets))
            })();
            let (mut runtime, buckets) = match setup {
                Ok((r, b)) => {
                    let _ = ready_tx.send(Ok((b[0].2, b[0].3)));
                    (r, b)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let seq = buckets[0].2;
            let vocab = buckets[0].3;
            let sizes: Vec<usize> = buckets.iter().map(|b| b.0).collect();
            while let Some(batch) = next_batch(&rx, &policy) {
                // process in bucket-sized chunks (a linger window can
                // collect more than the largest compiled batch size)
                let mut remaining: &[Request] = &batch;
                while !remaining.is_empty() {
                    let t0 = Instant::now();
                    let n = remaining.len();
                    let bucket =
                        pick_bucket(&sizes, n).unwrap_or(*sizes.last().unwrap());
                    let take = n.min(bucket);
                    let (now, rest) = remaining.split_at(take);
                    remaining = rest;
                    let artifact =
                        &buckets.iter().find(|b| b.0 == bucket).unwrap().1;
                    // assemble padded token matrix
                    let mut toks = vec![0i32; bucket * seq];
                    let mut bad: Vec<usize> = Vec::new();
                    for (i, r) in now.iter().enumerate() {
                        if r.tokens.len() != seq
                            || r.tokens.iter().any(|&t| t < 0 || t as usize >= vocab)
                        {
                            bad.push(i);
                            continue;
                        }
                        toks[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
                    }
                    let result = literal_i32(&toks, &[bucket, seq])
                        .and_then(|lit| runtime.execute_f32(artifact, &[lit]));
                    match result {
                        Ok(logits) => {
                            // record before replying so snapshots taken by a
                            // caller right after its reply see this batch
                            metrics_w
                                .record_batch(take, t0.elapsed().as_micros() as f64);
                            let per_row = seq * vocab;
                            for (i, r) in now.iter().enumerate() {
                                let reply = if bad.contains(&i) {
                                    metrics_w.record_error();
                                    Err(anyhow!(
                                        "invalid request: need {seq} tokens in [0, {vocab})"
                                    ))
                                } else {
                                    Ok(logits[i * per_row..(i + 1) * per_row].to_vec())
                                };
                                let _ = r.resp.send(reply);
                            }
                        }
                        Err(e) => {
                            metrics_w.record_error();
                            for r in now {
                                let _ =
                                    r.resp.send(Err(anyhow!("execution failed: {e}")));
                            }
                        }
                    }
                }
            }
        });

        let (seq, vocab) = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))??;
        Ok(InferenceServer {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            seq,
            vocab,
        })
    }

    /// Blocking inference: returns per-position logits (seq * vocab).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Vec<f32>> {
        let (rtx, rrx) = channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(Request { tokens, resp: rtx })
            .map_err(|_| anyhow!("server worker gone"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel -> worker drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
