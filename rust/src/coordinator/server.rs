//! Threaded batching inference server — the L3 request loop.
//!
//! Architecture (tokio-free; DESIGN.md §1): callers submit token
//! sequences through a channel; a dedicated worker thread owns the
//! execution backend, batches requests (`batching::next_batch`),
//! executes, and answers each caller through its response channel.
//!
//! Two backends ([`Backend`]):
//! * [`Backend::Pjrt`] — the AOT-compiled `tiny_lm_b{N}` artifacts via
//!   the PJRT [`Runtime`]; batches are padded to the nearest compiled
//!   batch bucket. Requires `make artifacts` and a PJRT-enabled build.
//! * [`Backend::CimSim`] — the emulated-crossbar batched decode engine
//!   (`sim::decode::BatchDecodeEngine`) behind a **continuous batching**
//!   loop with **chunked prefill**: `policy.max_batch` sequence slots
//!   share one programmed chip, requests (ragged windows of 1..=seq
//!   tokens) are admitted into free slots *between steps*, every step
//!   advances all in-flight windows through a single batched plan
//!   replay — a freshly admitted request ingesting up to
//!   `prefill_chunk` prompt positions per replay (lanes = positions,
//!   `sim::prefill`) while neighbours keep their lanes — and finished
//!   slots are evicted and refilled without stalling anyone. Per-lane
//!   bit-identicality of the batched replay means a request's logits
//!   never depend on who it shared the chip with, or on how its prompt
//!   was chunked. Needs no artifacts — this is the self-contained
//!   serving path of the offline image. [`Metrics`] additionally
//!   reports per-step slot occupancy, wall-clock tokens/sec, and the
//!   per-request time-to-first-token / inter-token latency split.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batching::{next_batch, pick_bucket, BatchPolicy};
use super::metrics::Metrics;
use crate::cim::CimParams;
use crate::mapping::Strategy;
use crate::model::ModelConfig;
use crate::runtime::{literal_i32, Runtime};
use crate::sim::decode::{argmax, BatchDecodeEngine, DecodeModel};
use crate::sim::prefill::allocate_chunks;
use crate::sim::speculate::self_draft_model;
use crate::sim::trace::sum_costs;
use crate::util::json::Json;

/// One discovered `tiny_lm` artifact bucket:
/// `(batch, artifact name, seq, vocab)`.
type Bucket = (usize, String, usize, usize);

/// One inference request: a token window answered with per-position
/// logits.
struct Request {
    tokens: Vec<i32>,
    resp: Sender<Result<Vec<f32>>>,
    /// Submission time — queue wait counts toward the request's
    /// recorded latency (a request can sit in the channel while every
    /// slot is busy).
    t0: Instant,
}

/// CIM-sim backend configuration.
#[derive(Clone, Debug)]
pub struct CimSimConfig {
    pub model: ModelConfig,
    pub strategy: Strategy,
    pub cim: CimParams,
    /// Weight-synthesis seed (deterministic across servers).
    pub seed: u64,
    /// Chunked-prefill width: how many prompt positions one admitted
    /// request may ingest per batched replay (`sim::prefill`). `0`
    /// (default) derives the chunk from the batch lane budget — the slot
    /// capacity — so an idle chip prefills as wide as a full decode
    /// step. Whatever the setting, in-flight neighbours always keep
    /// their decode lane (`batching::prefill_lane_budget`).
    pub prefill_chunk: usize,
    /// Speculative decoding (`sim::speculate`, DESIGN.md §6d): when
    /// `> 0`, a draft model races ahead of each in-flight window and
    /// every verify replay spans the agreed run plus one correction
    /// position (up to K proposals per round). `0` (default) disables
    /// speculation entirely — the worker is byte-identical to the plain
    /// chunked-prefill path. Scores are bit-identical either way;
    /// speculation only changes how positions group into replays, and
    /// [`Metrics`] gains acceptance-rate / tokens-per-round counters.
    pub speculate_k: usize,
    /// Draft depth for speculation: the self-draft keeps this many of
    /// the target's decoder layers (`sim::speculate::self_draft_model`).
    /// `0` (default) means full depth — a perfect draft. Ignored when
    /// `speculate_k == 0`.
    pub draft_layers: usize,
    /// Layer-sharded pipeline (`sim::shard`, DESIGN.md §6f): when
    /// `> 1`, the decoder's layers are programmed across this many
    /// stage chips (clamped to the layer count) driven as a pipeline
    /// with in-flight microbatches, and [`Metrics`] gains per-stage
    /// occupancy and pipeline-bubble counters. `0`/`1` (default)
    /// serves on one chip. Scores are bit-identical either way —
    /// sharding only changes which chip replays which layer
    /// (`tests/prop_shard.rs`).
    pub shards: usize,
}

impl Default for CimSimConfig {
    fn default() -> Self {
        Self {
            model: ModelConfig::tiny(),
            strategy: Strategy::DenseMap,
            cim: CimParams::default(),
            seed: 2025,
            prefill_chunk: 0,
            speculate_k: 0,
            draft_layers: 0,
            shards: 1,
        }
    }
}

/// Execution backend of the server worker.
#[derive(Clone, Debug, Default)]
pub enum Backend {
    /// PJRT-executed AOT artifacts (the original path).
    #[default]
    Pjrt,
    /// Emulated crossbar chip (`sim::decode`), no artifacts needed.
    CimSim(CimSimConfig),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
    pub backend: Backend,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            policy: BatchPolicy::default(),
            backend: Backend::Pjrt,
        }
    }
}

impl ServerConfig {
    /// Convenience: a CIM-sim server with the default tiny model.
    pub fn cim_sim(strategy: Strategy) -> ServerConfig {
        ServerConfig {
            backend: Backend::CimSim(CimSimConfig {
                strategy,
                ..Default::default()
            }),
            ..Default::default()
        }
    }
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub seq: usize,
    pub vocab: usize,
}

/// Validate one request window against the PJRT artifact contract
/// (fixed-length windows — the AOT graphs are compiled for exactly
/// `seq` positions).
fn validate(tokens: &[i32], seq: usize, vocab: usize) -> Result<()> {
    if tokens.len() != seq || tokens.iter().any(|&t| t < 0 || t as usize >= vocab) {
        bail!("invalid request: need {seq} tokens in [0, {vocab})");
    }
    Ok(())
}

/// Validate one request window for the CIM-sim backend: the decode
/// engine scores token by token, so any ragged window of 1..=seq
/// positions is servable (continuous batching admits mixed lengths).
fn validate_window(tokens: &[i32], seq: usize, vocab: usize) -> Result<()> {
    if tokens.is_empty()
        || tokens.len() > seq
        || tokens.iter().any(|&t| t < 0 || t as usize >= vocab)
    {
        bail!("invalid request: need 1..={seq} tokens in [0, {vocab})");
    }
    Ok(())
}

/// Pick the artifact for an `n`-request chunk: the smallest compiled
/// batch bucket that fits, else the largest available (the chunk is
/// then split across executions). Returns a structured error instead of
/// panicking when the bucket table is empty or inconsistent — a
/// malformed manifest must fail the requests, not kill the worker
/// thread.
fn select_artifact(buckets: &[Bucket], n: usize) -> Result<(usize, &str)> {
    let sizes: Vec<usize> = buckets.iter().map(|b| b.0).collect();
    let bucket = match pick_bucket(&sizes, n) {
        Some(b) => b,
        None => *sizes
            .last()
            .ok_or_else(|| anyhow!("no compiled batch buckets available"))?,
    };
    let artifact = buckets
        .iter()
        .find(|b| b.0 == bucket)
        .map(|b| b.1.as_str())
        .ok_or_else(|| anyhow!("no artifact compiled for batch bucket {bucket}"))?;
    Ok((bucket, artifact))
}

/// Fail every request of a chunk with a structured error reply: the
/// worker stays alive and each caller's `recv` resolves to an `Err`
/// instead of hanging on a dropped channel.
fn fail_chunk(reqs: &[Request], err: &anyhow::Error, metrics: &Metrics) {
    metrics.record_error();
    for r in reqs {
        let _ = r.resp.send(Err(anyhow!("batch scheduling failed: {err}")));
    }
}

/// Worker loop for the PJRT backend.
fn run_pjrt_worker(
    dir: std::path::PathBuf,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    rx: Receiver<Request>,
    ready_tx: Sender<Result<(usize, usize)>>,
) {
    // --- startup: build runtime + discover tiny_lm buckets ---
    let setup = (|| -> Result<(Runtime, Vec<Bucket>)> {
        let mut runtime = Runtime::new(&dir)?;
        let mut buckets: Vec<Bucket> = Vec::new();
        for a in &runtime.manifest().artifacts {
            if a.meta.get("kind").and_then(Json::as_str) == Some("tiny_lm") {
                let batch = a
                    .meta
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tiny_lm artifact missing batch"))?;
                let seq = a.meta.get("seq").and_then(Json::as_usize).unwrap_or(0);
                let vocab = a.meta.get("vocab").and_then(Json::as_usize).unwrap_or(0);
                buckets.push((batch, a.name.clone(), seq, vocab));
            }
        }
        if buckets.is_empty() {
            bail!("no tiny_lm artifacts in manifest — run `make artifacts`");
        }
        buckets.sort();
        // eager compile so first-request latency is steady-state
        for (_, name, _, _) in &buckets {
            runtime.load(name).context("precompiling artifact")?;
        }
        Ok((runtime, buckets))
    })();
    let (mut runtime, buckets) = match setup {
        Ok((r, b)) => {
            let _ = ready_tx.send(Ok((b[0].2, b[0].3)));
            (r, b)
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let seq = buckets[0].2;
    let vocab = buckets[0].3;
    while let Some(batch) = next_batch(&rx, &policy) {
        // process in bucket-sized chunks (a linger window can collect
        // more than the largest compiled batch size)
        let mut remaining: &[Request] = &batch;
        while !remaining.is_empty() {
            let t0 = Instant::now();
            let n = remaining.len();
            let (bucket, artifact) = match select_artifact(&buckets, n) {
                Ok(sel) => sel,
                Err(e) => {
                    // structured reply instead of a worker-killing panic
                    fail_chunk(remaining, &e, &metrics);
                    remaining = &[];
                    continue;
                }
            };
            let take = n.min(bucket);
            let (now, rest) = remaining.split_at(take);
            remaining = rest;
            // assemble padded token matrix; O(1) membership mask instead
            // of a per-reply linear scan over a bad-index list
            let mut toks = vec![0i32; bucket * seq];
            let mut bad = vec![false; take];
            for (i, r) in now.iter().enumerate() {
                if validate(&r.tokens, seq, vocab).is_err() {
                    bad[i] = true;
                    continue;
                }
                toks[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
            }
            let result = literal_i32(&toks, &[bucket, seq])
                .and_then(|lit| runtime.execute_f32(artifact, &[lit]));
            match result {
                Ok(logits) => {
                    // record before replying so snapshots taken by a
                    // caller right after its reply see this batch
                    metrics.record_batch(take, t0.elapsed().as_micros() as f64);
                    let per_row = seq * vocab;
                    for (i, r) in now.iter().enumerate() {
                        let reply = if bad[i] {
                            metrics.record_error();
                            Err(anyhow!(
                                "invalid request: need {seq} tokens in [0, {vocab})"
                            ))
                        } else {
                            Ok(logits[i * per_row..(i + 1) * per_row].to_vec())
                        };
                        let _ = r.resp.send(reply);
                    }
                }
                Err(e) => {
                    metrics.record_error();
                    for r in now {
                        let _ = r.resp.send(Err(anyhow!("execution failed: {e}")));
                    }
                }
            }
        }
    }
}

/// One in-flight CIM-sim request: the token window being scored, how
/// many positions have been fed, the per-position logits accumulated so
/// far, the reply channel, and the phase-timing marks the TTFT /
/// inter-token latency split is computed from.
struct InFlight {
    tokens: Vec<i32>,
    fed: usize,
    out: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    t0: Instant,
    /// Wall time (µs since submission) at which the request's first
    /// logits existed — set after its first stepped chunk.
    ttft_us: Option<f64>,
    /// Positions covered by that first chunk (the TTFT phase).
    first_chunk: usize,
}

/// Speculative chunk sizing for one in-flight window (ISSUE 5,
/// `sim::speculate` adapted to teacher-forced scoring): the draft races
/// ahead of the slot's scored prefix, and the next verify chunk spans
/// the agreed run plus one correction position — `accepted + 1` window
/// positions, the exact generation-side round shape. The served window
/// is the ground truth here, so a mismatched proposal is simply never
/// fed and **no rollback is needed**; what the counters measure is how
/// far the draft would have carried a real decode. Scores are
/// unaffected either way: chunking never changes what a position
/// computes (`tests/prop_prefill.rs`).
fn speculative_want(
    draft: &mut BatchDecodeEngine,
    slot: usize,
    window: &[i32],
    fed: usize,
    speculate_k: usize,
    metrics: &Metrics,
) -> usize {
    let remaining = window.len() - fed;
    let kprop = speculate_k.min(remaining - 1);
    if kprop == 0 {
        // window tail: an ordinary decode-pace step — the draft has
        // nothing to buy here, so it does no work (this is always the
        // slot's last step; nothing later depends on its draft state)
        return 1;
    }
    // resync the draft to the scored prefix: it can sit ahead if a
    // previous verify chunk was cut by the lane allocator — roll it
    // back one short and re-step so its logits predict position `fed`
    if draft.kv_len(slot) > fed {
        draft.truncate_kv(slot, fed - 1);
    }
    if draft.kv_len(slot) < fed {
        let from = draft.kv_len(slot);
        draft.step_chunks(&[(slot, &window[from..fed])]);
    }
    let mut acc = 0usize;
    while acc < kprop {
        let d = argmax(draft.logits(slot)) as i32;
        if d != window[fed + acc] {
            break;
        }
        // the proposal matched: advance the draft over the confirmed
        // ground-truth token and keep racing
        acc += 1;
        draft.step_chunks(&[(slot, &window[fed + acc - 1..fed + acc])]);
    }
    metrics.record_speculation(kprop, acc);
    acc + 1
}

/// Worker loop for the CIM-sim backend: a continuous-batching scheduler
/// over ONE [`BatchDecodeEngine`] owned by the worker thread. The chip
/// is programmed once; `policy.max_batch` sequence slots share it.
///
/// Each iteration: (1) **admit** — free slots are filled from the
/// request queue (blocking only when the chip is idle, so admission
/// never stalls in-flight sequences); (2) **step** — every occupied
/// slot advances through a single batched plan replay, by a *chunk* of
/// up to `prefill_chunk` positions of its window
/// (`BatchDecodeEngine::step_chunks`, lanes = positions): a freshly
/// admitted prompt ingests position-parallel while its neighbours keep
/// stepping, with per-step lanes bounded by
/// `batching::prefill_lane_budget` + `sim::prefill::allocate_chunks`
/// so no in-flight request is ever starved of its lane; (3) **evict**
/// — slots whose window is fully scored reply with their per-position
/// logits and free the slot for the next waiting request. The worker
/// drains naturally on shutdown: queued requests are still admitted
/// after the channel closes, and in-flight ones run to completion.
///
/// [`Metrics`] records, besides occupancy and modeled chip cost, the
/// per-request **TTFT / inter-token split** (`record_request_timing`)
/// and the prefill chunk counters — the honest view of what chunked
/// ingestion buys (time-to-first-token) and what it leaves unchanged
/// (the decode cadence).
///
/// With `speculate_k > 0` a layer-truncated self-draft (its own chip,
/// one draft slot per target slot) sizes each window's chunks
/// speculatively ([`speculative_want`]): the verify replay spans the
/// draft-agreed run plus one correction position, and the
/// acceptance-rate / tokens-per-round counters land in [`Metrics`].
/// `speculate_k == 0` leaves this worker byte-identical to the plain
/// chunked-prefill path.
///
/// Because the engine is constructed once and reused, its compiled
/// execution plan, chip pass scratch and the shared chunk workspace
/// are reused across every request this worker ever serves — the
/// steady-state serving path performs no per-pass allocation.
fn run_cimsim_worker(
    cfg: CimSimConfig,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    rx: Receiver<Request>,
    ready_tx: Sender<Result<(usize, usize)>>,
) {
    let CimSimConfig {
        model: model_cfg,
        strategy,
        cim,
        seed,
        prefill_chunk,
        speculate_k,
        draft_layers,
        shards,
    } = cfg;
    let (seq, vocab) = (model_cfg.seq, model_cfg.vocab);
    let slots = policy.max_batch.max(1);
    // chunk 0 = auto: prefill as wide as the batch lane budget allows
    let chunk = if prefill_chunk == 0 { slots } else { prefill_chunk }.max(1);
    // with speculation, a verify chunk spans at most K + 1 lanes per
    // slot — widen the budget so agreed runs are not cut mid-race (the
    // draft resync path below tolerates cuts regardless)
    let lane_budget = super::batching::prefill_lane_budget(slots, chunk)
        .max(if speculate_k > 0 { slots * (speculate_k + 1) } else { 0 });
    let setup = (move || -> Result<(BatchDecodeEngine, Option<BatchDecodeEngine>)> {
        if model_cfg.enc_layers != 0 || model_cfg.dec_layers == 0 {
            bail!(
                "CIM-sim backend needs a decoder-only model, got {}",
                model_cfg.name
            );
        }
        let b = (model_cfg.d_model as f64).sqrt().round() as usize;
        if b * b != model_cfg.d_model || b > cim.array_dim {
            bail!(
                "model d_model {} incompatible with array dim {}",
                model_cfg.d_model,
                cim.array_dim
            );
        }
        // speculation: a layer-truncated self-draft on its own chip,
        // with one draft slot mirroring each target slot (per-request
        // draft KV for concurrent ragged windows)
        let draft = if speculate_k > 0 {
            // draft_layers 0 = full depth (self_draft_model's contract)
            let dmodel = self_draft_model(&model_cfg, seed, draft_layers);
            Some(BatchDecodeEngine::on_chip(dmodel, cim.clone(), strategy, slots))
        } else {
            None
        };
        let model = DecodeModel::synth(model_cfg, seed);
        // shards > 1: layer-sharded pipeline engine (bit-identical
        // scores; adds the per-stage timeline behind the new counters)
        let engine = if shards > 1 {
            BatchDecodeEngine::sharded(model, cim, strategy, slots, shards)
        } else {
            BatchDecodeEngine::on_chip(model, cim, strategy, slots)
        };
        Ok((engine, draft))
    })();
    let (mut engine, mut draft) = match setup {
        Ok(p) => {
            let _ = ready_tx.send(Ok((seq, vocab)));
            p
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let capacity = engine.capacity();
    let mut active: Vec<Option<InFlight>> = (0..capacity).map(|_| None).collect();
    let mut open = true; // request channel still connected
    // per-step (slot, chunk length) plan + chunk wants, reused buffers
    let mut step_plan: Vec<(usize, usize)> = Vec::with_capacity(capacity);
    let mut wants: Vec<usize> = Vec::with_capacity(capacity);
    loop {
        // --- admit: fill free slots between token steps ---
        while open && engine.occupancy() < capacity {
            let req = if engine.occupancy() == 0 {
                // idle chip: block until work arrives (or shutdown)
                match rx.recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            } else {
                // busy chip: opportunistic, never stalls the batch
                match rx.try_recv() {
                    Ok(r) => Some(r),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            let Some(req) = req else { break };
            if let Err(e) = validate_window(&req.tokens, seq, vocab) {
                metrics.record_error();
                let _ = req.resp.send(Err(e));
                continue;
            }
            let slot = engine.try_admit().expect("occupancy < capacity");
            if let Some(d) = draft.as_mut() {
                // admissions and releases are paired, so both pools have
                // identical free sets and hand out the same slot index
                let ds = d.try_admit().expect("draft pool mirrors the target pool");
                debug_assert_eq!(ds, slot, "draft slot diverged from target slot");
            }
            let window = req.tokens.len();
            active[slot] = Some(InFlight {
                tokens: req.tokens,
                fed: 0,
                out: Vec::with_capacity(window * vocab),
                resp: req.resp,
                t0: req.t0, // submission time, so queue wait is counted
                ttft_us: None,
                first_chunk: 0,
            });
        }
        if engine.occupancy() == 0 {
            if open {
                continue; // raced an invalid request; go back to recv
            }
            break; // drained and disconnected
        }
        // --- step: advance every in-flight window by one chunk ---
        // Every occupied slot wants up to `chunk` of its remaining
        // positions; the allocator floors each at one lane (no
        // starvation) and bounds the step's total lane count.
        step_plan.clear();
        wants.clear();
        for (slot, a) in active.iter().enumerate() {
            if let Some(a) = a {
                step_plan.push((slot, 0));
                let want = match draft.as_mut() {
                    // speculative chunking needs a scored prefix for the
                    // draft to continue from; the first chunk of a window
                    // prefills normally
                    Some(d) if a.fed > 0 => speculative_want(
                        d,
                        slot,
                        &a.tokens,
                        a.fed,
                        speculate_k,
                        &metrics,
                    ),
                    _ => (a.tokens.len() - a.fed).min(chunk),
                };
                wants.push(want);
            }
        }
        let alloc = allocate_chunks(&wants, lane_budget);
        for (p, &c) in step_plan.iter_mut().zip(&alloc) {
            p.1 = c;
        }
        {
            let groups: Vec<(usize, &[i32])> = step_plan
                .iter()
                .map(|&(slot, c)| {
                    let a = active[slot].as_ref().expect("planned slot is active");
                    (slot, &a.tokens[a.fed..a.fed + c])
                })
                .collect();
            engine.step_chunks(&groups);
        }
        metrics.record_occupancy(step_plan.len(), capacity);
        // sharded engine: drain the step's pipeline window into the
        // shared metrics (no-op on the mono path — zero steps recorded)
        let ps = engine.take_pipeline_stats();
        metrics.record_pipeline(
            ps.steps,
            &ps.stage_busy_ns,
            ps.span_ns,
            ps.transfer_ns,
            ps.serial_ns,
        );
        // --- evict: finished windows reply and free their slot ---
        let mut finished: Vec<InFlight> = Vec::new();
        let mut lane = 0usize;
        for &(slot, c) in &step_plan {
            let a = active[slot].as_mut().expect("stepped slot is active");
            // stream this chunk's per-position logits (flattened lane
            // order matches the step_plan group order)
            for i in 0..c {
                a.out.extend_from_slice(engine.lane_logits(lane + i));
            }
            lane += c;
            if a.fed == 0 {
                // first logits of this request now exist: TTFT
                a.ttft_us = Some(a.t0.elapsed().as_micros() as f64);
                a.first_chunk = c;
            }
            // prefill counters mean *prompt-ingestion* chunks; verify
            // chunks sized by the draft (every post-first chunk when
            // speculation is on) are counted by record_speculation
            if c > 1 && (draft.is_none() || a.fed == 0) {
                metrics.record_prefill_chunk(c);
            }
            a.fed += c;
            if a.fed == a.tokens.len() {
                let costs = engine.take_trace(slot);
                let total = sum_costs(&costs);
                metrics.record_sim_tokens(
                    a.tokens.len(),
                    total.latency.critical_ns(),
                    total.energy.total_nj(),
                );
                let total_us = a.t0.elapsed().as_micros() as f64;
                let ttft = a.ttft_us.unwrap_or(total_us);
                let tail = a.tokens.len().saturating_sub(a.first_chunk);
                let inter = if tail > 0 {
                    Some((total_us - ttft).max(0.0) / tail as f64)
                } else {
                    None
                };
                metrics.record_request_timing(ttft, inter);
                engine.release(slot);
                if let Some(d) = draft.as_mut() {
                    d.release(slot);
                }
                finished.push(active[slot].take().expect("finished slot"));
            }
        }
        if !finished.is_empty() {
            // record before replying so snapshots taken by a caller
            // right after its reply see this completion group (same
            // invariant as the PJRT worker); per-request latencies keep
            // the percentiles honest under ragged admission times
            let latencies: Vec<f64> = finished
                .iter()
                .map(|f| f.t0.elapsed().as_micros() as f64)
                .collect();
            metrics.record_completions(&latencies);
            for f in finished {
                let _ = f.resp.send(Ok(f.out));
            }
        }
    }
}

impl InferenceServer {
    /// Start the worker thread (loads + compiles the backend eagerly).
    ///
    /// The PJRT client is not `Send`, so the backend is constructed
    /// *inside* the worker thread; readiness (or the startup error) is
    /// reported back through a one-shot channel.
    pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
        let metrics = Arc::new(Metrics::new());
        let metrics_w = metrics.clone();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize)>>();
        let policy = cfg.policy.clone();
        let worker = match cfg.backend {
            Backend::Pjrt => {
                let dir = cfg.artifacts_dir.clone();
                std::thread::spawn(move || {
                    run_pjrt_worker(dir, policy, metrics_w, rx, ready_tx)
                })
            }
            Backend::CimSim(sim_cfg) => std::thread::spawn(move || {
                run_cimsim_worker(sim_cfg, policy, metrics_w, rx, ready_tx)
            }),
        };

        let (seq, vocab) = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))??;
        Ok(InferenceServer {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            seq,
            vocab,
        })
    }

    /// Blocking inference: returns per-position logits (window len *
    /// vocab; the CIM-sim backend accepts ragged windows of 1..=seq).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Vec<f32>> {
        let (rtx, rrx) = channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(Request {
                tokens,
                resp: rtx,
                t0: Instant::now(),
            })
            .map_err(|_| anyhow!("server worker gone"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel -> worker drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bucket_table() -> Vec<Bucket> {
        [1usize, 2, 4, 8]
            .iter()
            .map(|&b| (b, format!("tiny_lm_b{b}"), 8, 16))
            .collect()
    }

    #[test]
    fn select_artifact_picks_smallest_fitting_bucket() {
        let buckets = bucket_table();
        let (bucket, artifact) = select_artifact(&buckets, 3).unwrap();
        assert_eq!(bucket, 4);
        assert_eq!(artifact, "tiny_lm_b4");
        let (bucket, artifact) = select_artifact(&buckets, 1).unwrap();
        assert_eq!(bucket, 1);
        assert_eq!(artifact, "tiny_lm_b1");
    }

    #[test]
    fn select_artifact_falls_back_to_largest_bucket() {
        // an oversize chunk takes the largest compiled batch; the
        // worker then splits the chunk and loops
        let buckets = bucket_table();
        let (bucket, artifact) = select_artifact(&buckets, 100).unwrap();
        assert_eq!(bucket, 8);
        assert_eq!(artifact, "tiny_lm_b8");
    }

    #[test]
    fn select_artifact_on_empty_table_is_an_error_not_a_panic() {
        // regression: this path used to `unwrap` a `sizes.last()` of an
        // empty table, killing the worker thread with every caller's
        // reply channel still open
        let err = select_artifact(&[], 5).unwrap_err();
        assert!(
            err.to_string().contains("no compiled batch buckets"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn failed_chunk_round_trips_error_replies_and_keeps_channels_alive() {
        // every request of a failed chunk must receive a structured
        // error reply (no hung `recv`, no panic), and the failure must
        // land in the error counter exactly once per chunk
        let metrics = Metrics::new();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (rtx, rrx) = channel();
            reqs.push(Request {
                tokens: vec![0, 1, 2],
                resp: rtx,
                t0: Instant::now(),
            });
            rxs.push(rrx);
        }
        let err = select_artifact(&[], reqs.len()).unwrap_err();
        fail_chunk(&reqs, &err, &metrics);
        for rrx in rxs {
            let reply = rrx.recv().expect("reply channel must stay alive");
            let msg = reply.expect_err("chunk failed, reply must be Err").to_string();
            assert!(
                msg.contains("batch scheduling failed"),
                "unexpected reply: {msg}"
            );
            assert!(msg.contains("no compiled batch buckets"), "cause lost: {msg}");
        }
        assert_eq!(metrics.snapshot().errors, 1);
    }
}
