//! Threaded batching inference server — the L3 request loop.
//!
//! Architecture (tokio-free; DESIGN.md §1): callers submit token
//! sequences through a channel; a dedicated worker thread owns the
//! execution backend, batches requests (`batching::next_batch`),
//! executes, and answers each caller through its response channel.
//!
//! Two backends ([`Backend`]):
//! * [`Backend::Pjrt`] — the AOT-compiled `tiny_lm_b{N}` artifacts via
//!   the PJRT [`Runtime`]; batches are padded to the nearest compiled
//!   batch bucket. Requires `make artifacts` and a PJRT-enabled build.
//! * [`Backend::CimSim`] — the emulated-crossbar batched decode engine
//!   (`sim::decode::BatchDecodeEngine`) behind a **continuous batching**
//!   loop with **chunked prefill**: `policy.max_batch` sequence slots
//!   share one programmed chip, requests (ragged windows of 1..=seq
//!   tokens) are admitted into free slots *between steps*, every step
//!   advances all in-flight windows through a single batched plan
//!   replay — a freshly admitted request ingesting up to
//!   `prefill_chunk` prompt positions per replay (lanes = positions,
//!   `sim::prefill`) while neighbours keep their lanes — and finished
//!   slots are evicted and refilled without stalling anyone. Per-lane
//!   bit-identicality of the batched replay means a request's logits
//!   never depend on who it shared the chip with, or on how its prompt
//!   was chunked. Needs no artifacts — this is the self-contained
//!   serving path of the offline image. [`Metrics`] additionally
//!   reports per-step slot occupancy, wall-clock tokens/sec, and the
//!   per-request time-to-first-token / inter-token latency split.
//!
//! The CIM-sim backend scales out (DESIGN.md §6g): `workers: W` spawns
//! W independent continuous-batching workers — each its own programmed
//! chip, identical weights from the shared synthesis seed, so any
//! worker serves any request bit-identically — pulling from one shared
//! [`RequestQueue`] (work-stealing dispatch; `std::sync::mpsc`
//! receivers are neither cloneable nor `Sync`, hence the
//! mutex-and-condvar queue). Each worker keeps a per-worker
//! shared-prefix KV cache (`coordinator::prefix`): completed windows
//! donate KV + logits, and an admission whose window opens with a
//! cached prefix splices that state in (`BatchDecodeEngine::splice_kv`)
//! instead of prefilling it. Clients that vanish are detected through a
//! liveness token on each request ([`InferenceServer::submit`] returns
//! a [`PendingResponse`] holding it): a dropped handle releases the
//! slot at the next step boundary and counts a cancellation instead of
//! decoding for nobody.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batching::{next_batch, pick_bucket, BatchPolicy};
use super::metrics::Metrics;
use super::prefix::PrefixStore;
use super::tracing::{Event, EventKind, Tracer};
use crate::cim::CimParams;
use crate::mapping::Strategy;
use crate::model::ModelConfig;
use crate::runtime::{literal_i32, Runtime};
use crate::sim::decode::{argmax, BatchDecodeEngine, DecodeModel};
use crate::sim::prefill::allocate_chunks;
use crate::sim::speculate::self_draft_model;
use crate::sim::trace::sum_costs;
use crate::util::json::Json;

/// One discovered `tiny_lm` artifact bucket:
/// `(batch, artifact name, seq, vocab)`.
type Bucket = (usize, String, usize, usize);

/// One inference request: a token window answered with per-position
/// logits.
struct Request {
    tokens: Vec<i32>,
    resp: Sender<Result<Vec<f32>>>,
    /// Client-liveness token: the submitting side holds the [`Arc`]
    /// (inside [`PendingResponse`]); when the upgrade fails the client
    /// is gone and the worker may drop the request or release its slot
    /// early (`std::sync::mpsc` senders cannot observe a dropped
    /// receiver, so liveness rides its own handle).
    alive: Weak<()>,
    /// Submission time — queue wait counts toward the request's
    /// recorded latency (a request can sit in the channel while every
    /// slot is busy).
    t0: Instant,
    /// Tracing id assigned at submission (0 when tracing is off).
    id: u64,
}

/// Outcome of a non-blocking [`RequestQueue::try_pop`].
enum TryPop {
    Item(Request),
    Empty,
    Closed,
}

/// Shared dispatch queue for the multi-worker CIM-sim backend:
/// `std::sync::mpsc` receivers are neither cloneable nor `Sync`, so W
/// workers instead pull from this mutex-and-condvar queue. Dispatch is
/// work-stealing by construction — an idle worker blocks in
/// [`RequestQueue::recv`], a busy one polls [`RequestQueue::try_pop`]
/// between steps — so load balances onto whichever chip has free slots
/// without a central scheduler. Semantics mirror the mpsc channel the
/// single-worker path used: pushes fail once closed, and queued
/// requests are still drained after close (graceful shutdown).
struct RequestQueue {
    state: Mutex<(VecDeque<Request>, bool)>,
    ready: Condvar,
}

impl RequestQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new((VecDeque::new(), false)),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a request; `Err` (with the request back) once closed.
    fn push(&self, r: Request) -> std::result::Result<(), Request> {
        let mut g = self.state.lock().unwrap();
        if g.1 {
            return Err(r);
        }
        g.0.push_back(r);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking pop: `None` only when the queue is closed AND drained.
    fn recv(&self) -> Option<Request> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(r) = g.0.pop_front() {
                return Some(r);
            }
            if g.1 {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Non-blocking pop for a busy worker between steps.
    fn try_pop(&self) -> TryPop {
        let mut g = self.state.lock().unwrap();
        match g.0.pop_front() {
            Some(r) => TryPop::Item(r),
            None if g.1 => TryPop::Closed,
            None => TryPop::Empty,
        }
    }

    /// Close the queue: pushes fail from here on, blocked workers wake,
    /// already-queued requests still drain.
    fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.ready.notify_all();
    }

    /// Requests currently waiting (the queue-depth counter track).
    fn depth(&self) -> usize {
        self.state.lock().unwrap().0.len()
    }
}

/// CIM-sim backend configuration.
#[derive(Clone, Debug)]
pub struct CimSimConfig {
    pub model: ModelConfig,
    pub strategy: Strategy,
    pub cim: CimParams,
    /// Weight-synthesis seed (deterministic across servers).
    pub seed: u64,
    /// Chunked-prefill width: how many prompt positions one admitted
    /// request may ingest per batched replay (`sim::prefill`). `0`
    /// (default) derives the chunk from the batch lane budget — the slot
    /// capacity — so an idle chip prefills as wide as a full decode
    /// step. Whatever the setting, in-flight neighbours always keep
    /// their decode lane (`batching::prefill_lane_budget`).
    pub prefill_chunk: usize,
    /// Speculative decoding (`sim::speculate`, DESIGN.md §6d): when
    /// `> 0`, a draft model races ahead of each in-flight window and
    /// every verify replay spans the agreed run plus one correction
    /// position (up to K proposals per round). `0` (default) disables
    /// speculation entirely — the worker is byte-identical to the plain
    /// chunked-prefill path. Scores are bit-identical either way;
    /// speculation only changes how positions group into replays, and
    /// [`Metrics`] gains acceptance-rate / tokens-per-round counters.
    pub speculate_k: usize,
    /// Draft depth for speculation: the self-draft keeps this many of
    /// the target's decoder layers (`sim::speculate::self_draft_model`).
    /// `0` (default) means full depth — a perfect draft. Ignored when
    /// `speculate_k == 0`.
    pub draft_layers: usize,
    /// Layer-sharded pipeline (`sim::shard`, DESIGN.md §6f): when
    /// `> 1`, the decoder's layers are programmed across this many
    /// stage chips (clamped to the layer count) driven as a pipeline
    /// with in-flight microbatches, and [`Metrics`] gains per-stage
    /// occupancy and pipeline-bubble counters. `0`/`1` (default)
    /// serves on one chip. Scores are bit-identical either way —
    /// sharding only changes which chip replays which layer
    /// (`tests/prop_shard.rs`).
    pub shards: usize,
    /// Worker pool width (DESIGN.md §6g): this many independent
    /// continuous-batching workers — each its own programmed chip with
    /// identical weights from the shared seed — pull from one shared
    /// request queue, so any worker serves any request bit-identically.
    /// `0`/`1` (default) is the single-worker path.
    pub workers: usize,
    /// Shared-prefix KV cache entries *per worker* (DESIGN.md §6g):
    /// completed windows donate KV + per-position logits, and an
    /// admission opening with a cached prefix splices that state in
    /// instead of prefilling it (bit-identical by construction,
    /// `tests/prop_prefix_cache.rs`). `0` (default) disables reuse —
    /// every request pays cold prefill, byte-identical to the PR-4
    /// path. Note `Metrics::sim_tokens` counts positions *replayed on
    /// the chip*, so cache hits reduce it by exactly
    /// `prefix_positions_saved`.
    pub prefix_cache: usize,
    /// Request-tracing sink (`coordinator::tracing`, DESIGN.md §6h):
    /// when set, every request's span tree and the per-worker step /
    /// occupancy / queue-depth timeline are recorded into the tracer's
    /// bounded rings for Perfetto export. `None` (default) disables
    /// tracing at zero cost — no ring exists and every trace site is a
    /// skipped `None` check; served logits are bit-identical either way
    /// (`tests/prop_tracing.rs`).
    pub trace: Option<Arc<Tracer>>,
}

impl Default for CimSimConfig {
    fn default() -> Self {
        Self {
            model: ModelConfig::tiny(),
            strategy: Strategy::DenseMap,
            cim: CimParams::default(),
            seed: 2025,
            prefill_chunk: 0,
            speculate_k: 0,
            draft_layers: 0,
            shards: 1,
            workers: 1,
            prefix_cache: 0,
            trace: None,
        }
    }
}

/// Execution backend of the server worker.
#[derive(Clone, Debug, Default)]
pub enum Backend {
    /// PJRT-executed AOT artifacts (the original path).
    #[default]
    Pjrt,
    /// Emulated crossbar chip (`sim::decode`), no artifacts needed.
    CimSim(CimSimConfig),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
    pub backend: Backend,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            policy: BatchPolicy::default(),
            backend: Backend::Pjrt,
        }
    }
}

impl ServerConfig {
    /// Convenience: a CIM-sim server with the default tiny model.
    pub fn cim_sim(strategy: Strategy) -> ServerConfig {
        ServerConfig {
            backend: Backend::CimSim(CimSimConfig {
                strategy,
                ..Default::default()
            }),
            ..Default::default()
        }
    }
}

/// Where submitted requests go: the PJRT worker's mpsc channel, or the
/// CIM-sim worker pool's shared queue.
enum Submitter {
    Channel(Sender<Request>),
    Queue(Arc<RequestQueue>),
}

impl Submitter {
    fn send(&self, r: Request) -> Result<()> {
        match self {
            Submitter::Channel(tx) => {
                tx.send(r).map_err(|_| anyhow!("server worker gone"))
            }
            Submitter::Queue(q) => {
                q.push(r).map_err(|_| anyhow!("server worker gone"))
            }
        }
    }

    /// Stop accepting requests; workers drain what is queued and exit.
    fn close(&self) {
        match self {
            // dropping the last Sender clone closes an mpsc channel;
            // the owning InferenceServer drops self right after close()
            Submitter::Channel(_) => {}
            Submitter::Queue(q) => q.close(),
        }
    }

    /// Waiting requests (mpsc depth is unobservable; reported as 0).
    fn depth(&self) -> usize {
        match self {
            Submitter::Channel(_) => 0,
            Submitter::Queue(q) => q.depth(),
        }
    }
}

/// Handle to one in-flight request submitted with
/// [`InferenceServer::submit`]. Await the logits with
/// [`PendingResponse::wait`]; **dropping the handle cancels the
/// request** — the worker notices the dead liveness token at its next
/// step boundary, releases the slot early and counts a cancellation
/// (`Metrics::cancellations`) instead of decoding for a client that
/// will never read the reply.
pub struct PendingResponse {
    rx: Receiver<Result<Vec<f32>>>,
    /// The strong end of the request's liveness token.
    _alive: Arc<()>,
}

impl PendingResponse {
    /// Block until the per-position logits (window len × vocab) arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: Option<Submitter>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub seq: usize,
    pub vocab: usize,
    /// Tracing sink shared with the CIM-sim workers (`None` when the
    /// backend has no tracer configured) — submission-side events
    /// (enqueue, queue depth) are recorded here.
    trace: Option<Arc<Tracer>>,
}

/// Validate one request window against the PJRT artifact contract
/// (fixed-length windows — the AOT graphs are compiled for exactly
/// `seq` positions).
fn validate(tokens: &[i32], seq: usize, vocab: usize) -> Result<()> {
    if tokens.len() != seq || tokens.iter().any(|&t| t < 0 || t as usize >= vocab) {
        bail!("invalid request: need {seq} tokens in [0, {vocab})");
    }
    Ok(())
}

/// Validate one request window for the CIM-sim backend: the decode
/// engine scores token by token, so any ragged window of 1..=seq
/// positions is servable (continuous batching admits mixed lengths).
fn validate_window(tokens: &[i32], seq: usize, vocab: usize) -> Result<()> {
    if tokens.is_empty()
        || tokens.len() > seq
        || tokens.iter().any(|&t| t < 0 || t as usize >= vocab)
    {
        bail!("invalid request: need 1..={seq} tokens in [0, {vocab})");
    }
    Ok(())
}

/// Pick the artifact for an `n`-request chunk: the smallest compiled
/// batch bucket that fits, else the largest available (the chunk is
/// then split across executions). Returns a structured error instead of
/// panicking when the bucket table is empty or inconsistent — a
/// malformed manifest must fail the requests, not kill the worker
/// thread.
fn select_artifact(buckets: &[Bucket], n: usize) -> Result<(usize, &str)> {
    let sizes: Vec<usize> = buckets.iter().map(|b| b.0).collect();
    let bucket = match pick_bucket(&sizes, n) {
        Some(b) => b,
        None => *sizes
            .last()
            .ok_or_else(|| anyhow!("no compiled batch buckets available"))?,
    };
    let artifact = buckets
        .iter()
        .find(|b| b.0 == bucket)
        .map(|b| b.1.as_str())
        .ok_or_else(|| anyhow!("no artifact compiled for batch bucket {bucket}"))?;
    Ok((bucket, artifact))
}

/// Fail every request of a chunk with a structured error reply: the
/// worker stays alive and each caller's `recv` resolves to an `Err`
/// instead of hanging on a dropped channel.
fn fail_chunk(reqs: &[Request], err: &anyhow::Error, metrics: &Metrics) {
    metrics.record_error();
    for r in reqs {
        let _ = r.resp.send(Err(anyhow!("batch scheduling failed: {err}")));
    }
}

/// Worker loop for the PJRT backend.
fn run_pjrt_worker(
    dir: std::path::PathBuf,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    rx: Receiver<Request>,
    ready_tx: Sender<Result<(usize, usize)>>,
) {
    // --- startup: build runtime + discover tiny_lm buckets ---
    let setup = (|| -> Result<(Runtime, Vec<Bucket>)> {
        let mut runtime = Runtime::new(&dir)?;
        let mut buckets: Vec<Bucket> = Vec::new();
        for a in &runtime.manifest().artifacts {
            if a.meta.get("kind").and_then(Json::as_str) == Some("tiny_lm") {
                let batch = a
                    .meta
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("tiny_lm artifact missing batch"))?;
                let seq = a.meta.get("seq").and_then(Json::as_usize).unwrap_or(0);
                let vocab = a.meta.get("vocab").and_then(Json::as_usize).unwrap_or(0);
                buckets.push((batch, a.name.clone(), seq, vocab));
            }
        }
        if buckets.is_empty() {
            bail!("no tiny_lm artifacts in manifest — run `make artifacts`");
        }
        buckets.sort();
        // eager compile so first-request latency is steady-state
        for (_, name, _, _) in &buckets {
            runtime.load(name).context("precompiling artifact")?;
        }
        Ok((runtime, buckets))
    })();
    let (mut runtime, buckets) = match setup {
        Ok((r, b)) => {
            let _ = ready_tx.send(Ok((b[0].2, b[0].3)));
            (r, b)
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let seq = buckets[0].2;
    let vocab = buckets[0].3;
    while let Some(batch) = next_batch(&rx, &policy) {
        // process in bucket-sized chunks (a linger window can collect
        // more than the largest compiled batch size)
        let mut remaining: &[Request] = &batch;
        while !remaining.is_empty() {
            let t0 = Instant::now();
            let n = remaining.len();
            let (bucket, artifact) = match select_artifact(&buckets, n) {
                Ok(sel) => sel,
                Err(e) => {
                    // structured reply instead of a worker-killing panic
                    fail_chunk(remaining, &e, &metrics);
                    remaining = &[];
                    continue;
                }
            };
            let take = n.min(bucket);
            let (now, rest) = remaining.split_at(take);
            remaining = rest;
            // assemble padded token matrix; O(1) membership mask instead
            // of a per-reply linear scan over a bad-index list
            let mut toks = vec![0i32; bucket * seq];
            let mut bad = vec![false; take];
            for (i, r) in now.iter().enumerate() {
                if validate(&r.tokens, seq, vocab).is_err() {
                    bad[i] = true;
                    continue;
                }
                toks[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
            }
            let result = literal_i32(&toks, &[bucket, seq])
                .and_then(|lit| runtime.execute_f32(artifact, &[lit]));
            match result {
                Ok(logits) => {
                    // record before replying so snapshots taken by a
                    // caller right after its reply see this batch
                    metrics.record_batch(take, t0.elapsed().as_micros() as f64);
                    let per_row = seq * vocab;
                    for (i, r) in now.iter().enumerate() {
                        let reply = if bad[i] {
                            metrics.record_error();
                            Err(anyhow!(
                                "invalid request: need {seq} tokens in [0, {vocab})"
                            ))
                        } else {
                            Ok(logits[i * per_row..(i + 1) * per_row].to_vec())
                        };
                        let _ = r.resp.send(reply);
                    }
                }
                Err(e) => {
                    metrics.record_error();
                    for r in now {
                        let _ = r.resp.send(Err(anyhow!("execution failed: {e}")));
                    }
                }
            }
        }
    }
}

/// One in-flight CIM-sim request: the token window being scored, how
/// many positions have been fed, the per-position logits accumulated so
/// far, the reply channel, and the phase-timing marks the TTFT /
/// inter-token latency split is computed from.
struct InFlight {
    tokens: Vec<i32>,
    /// Positions scored so far — starts at `spliced` when a prefix-
    /// cache hit seeded the slot (those positions' logits are already
    /// in `out`).
    fed: usize,
    /// Positions answered from the shared-prefix cache at admission
    /// (0 on a miss or with the cache disabled).
    spliced: usize,
    out: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    /// Client-liveness token (see [`Request::alive`]).
    alive: Weak<()>,
    t0: Instant,
    /// Wall time (µs since submission) at which the request's first
    /// logits existed — set after its first stepped chunk.
    ttft_us: Option<f64>,
    /// Positions covered by that first reply unit: the spliced prefix
    /// (if any) plus the first stepped chunk — the TTFT phase.
    first_chunk: usize,
    /// Tracing id carried over from the [`Request`] (0 = untraced).
    id: u64,
}

/// Speculative chunk sizing for one in-flight window (ISSUE 5,
/// `sim::speculate` adapted to teacher-forced scoring): the draft races
/// ahead of the slot's scored prefix, and the next verify chunk spans
/// the agreed run plus one correction position — `accepted + 1` window
/// positions, the exact generation-side round shape. The served window
/// is the ground truth here, so a mismatched proposal is simply never
/// fed and **no rollback is needed**; what the counters measure is how
/// far the draft would have carried a real decode. Scores are
/// unaffected either way: chunking never changes what a position
/// computes (`tests/prop_prefill.rs`).
fn speculative_want(
    draft: &mut BatchDecodeEngine,
    slot: usize,
    window: &[i32],
    fed: usize,
    speculate_k: usize,
    metrics: &Metrics,
) -> usize {
    let remaining = window.len() - fed;
    let kprop = speculate_k.min(remaining - 1);
    if kprop == 0 {
        // window tail: an ordinary decode-pace step — the draft has
        // nothing to buy here, so it does no work (this is always the
        // slot's last step; nothing later depends on its draft state)
        return 1;
    }
    // resync the draft to the scored prefix: it can sit ahead if a
    // previous verify chunk was cut by the lane allocator — roll it
    // back one short and re-step so its logits predict position `fed`
    if draft.kv_len(slot) > fed {
        draft.truncate_kv(slot, fed - 1);
    }
    if draft.kv_len(slot) < fed {
        let from = draft.kv_len(slot);
        draft.step_chunks(&[(slot, &window[from..fed])]);
    }
    let mut acc = 0usize;
    while acc < kprop {
        let d = argmax(draft.logits(slot)) as i32;
        if d != window[fed + acc] {
            break;
        }
        // the proposal matched: advance the draft over the confirmed
        // ground-truth token and keep racing
        acc += 1;
        draft.step_chunks(&[(slot, &window[fed + acc - 1..fed + acc])]);
    }
    metrics.record_speculation(kprop, acc);
    acc + 1
}

/// Worker loop for the CIM-sim backend: a continuous-batching scheduler
/// over ONE [`BatchDecodeEngine`] owned by the worker thread. The chip
/// is programmed once; `policy.max_batch` sequence slots share it.
///
/// Each iteration: (1) **admit** — free slots are filled from the
/// request queue (blocking only when the chip is idle, so admission
/// never stalls in-flight sequences); (2) **step** — every occupied
/// slot advances through a single batched plan replay, by a *chunk* of
/// up to `prefill_chunk` positions of its window
/// (`BatchDecodeEngine::step_chunks`, lanes = positions): a freshly
/// admitted prompt ingests position-parallel while its neighbours keep
/// stepping, with per-step lanes bounded by
/// `batching::prefill_lane_budget` + `sim::prefill::allocate_chunks`
/// so no in-flight request is ever starved of its lane; (3) **evict**
/// — slots whose window is fully scored reply with their per-position
/// logits and free the slot for the next waiting request. The worker
/// drains naturally on shutdown: queued requests are still admitted
/// after the channel closes, and in-flight ones run to completion.
///
/// [`Metrics`] records, besides occupancy and modeled chip cost, the
/// per-request **TTFT / inter-token split** (`record_request_timing`)
/// and the prefill chunk counters — the honest view of what chunked
/// ingestion buys (time-to-first-token) and what it leaves unchanged
/// (the decode cadence).
///
/// With `speculate_k > 0` a layer-truncated self-draft (its own chip,
/// one draft slot per target slot) sizes each window's chunks
/// speculatively ([`speculative_want`]): the verify replay spans the
/// draft-agreed run plus one correction position, and the
/// acceptance-rate / tokens-per-round counters land in [`Metrics`].
/// `speculate_k == 0` leaves this worker byte-identical to the plain
/// chunked-prefill path.
///
/// Because the engine is constructed once and reused, its compiled
/// execution plan, chip pass scratch and the shared chunk workspace
/// are reused across every request this worker ever serves — the
/// steady-state serving path performs no per-pass allocation.
///
/// Multi-worker serving (DESIGN.md §6g) runs W copies of this loop,
/// each with its own chip, pulling from the shared `queue` — `worker`
/// is this copy's index for the per-worker occupancy metric. Each
/// worker keeps its own [`PrefixStore`]: an admission whose window
/// opens with a cached prefix splices KV + logits from the store
/// (`BatchDecodeEngine::splice_kv`) and starts stepping at the first
/// uncovered position — bit-identical to cold prefill because K/V at a
/// position depend only on the tokens up to it. Requests whose client
/// vanished (the `alive` token no longer upgrades) are dropped at
/// admission or released at the next step boundary, counted as
/// cancellations; chip work already replayed for them stays on the
/// bill (the same rejected-work rule speculation uses).
fn run_cimsim_worker(
    worker: usize,
    cfg: CimSimConfig,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    queue: Arc<RequestQueue>,
    ready_tx: Sender<Result<(usize, usize)>>,
) {
    let CimSimConfig {
        model: model_cfg,
        strategy,
        cim,
        seed,
        prefill_chunk,
        speculate_k,
        draft_layers,
        shards,
        workers: _,
        prefix_cache,
        trace,
    } = cfg;
    // tracing (§6h): each worker owns its ring outright — recording is
    // a lock-free array write; `None` costs one skipped check per site
    let wid = worker as u32;
    let mut wt = trace.map(|t| t.worker(wid));
    let (seq, vocab) = (model_cfg.seq, model_cfg.vocab);
    let slots = policy.max_batch.max(1);
    // chunk 0 = auto: prefill as wide as the batch lane budget allows
    let chunk = if prefill_chunk == 0 { slots } else { prefill_chunk }.max(1);
    // with speculation, a verify chunk spans at most K + 1 lanes per
    // slot — widen the budget so agreed runs are not cut mid-race (the
    // draft resync path below tolerates cuts regardless)
    let lane_budget = super::batching::prefill_lane_budget(slots, chunk)
        .max(if speculate_k > 0 { slots * (speculate_k + 1) } else { 0 });
    let setup = (move || -> Result<(BatchDecodeEngine, Option<BatchDecodeEngine>)> {
        if model_cfg.enc_layers != 0 || model_cfg.dec_layers == 0 {
            bail!(
                "CIM-sim backend needs a decoder-only model, got {}",
                model_cfg.name
            );
        }
        let b = (model_cfg.d_model as f64).sqrt().round() as usize;
        if b * b != model_cfg.d_model || b > cim.array_dim {
            bail!(
                "model d_model {} incompatible with array dim {}",
                model_cfg.d_model,
                cim.array_dim
            );
        }
        // speculation: a layer-truncated self-draft on its own chip,
        // with one draft slot mirroring each target slot (per-request
        // draft KV for concurrent ragged windows)
        let draft = if speculate_k > 0 {
            // draft_layers 0 = full depth (self_draft_model's contract)
            let dmodel = self_draft_model(&model_cfg, seed, draft_layers);
            Some(BatchDecodeEngine::on_chip(dmodel, cim.clone(), strategy, slots))
        } else {
            None
        };
        let model = DecodeModel::synth(model_cfg, seed);
        // shards > 1: layer-sharded pipeline engine (bit-identical
        // scores; adds the per-stage timeline behind the new counters)
        let engine = if shards > 1 {
            BatchDecodeEngine::sharded(model, cim, strategy, slots, shards)
        } else {
            BatchDecodeEngine::on_chip(model, cim, strategy, slots)
        };
        Ok((engine, draft))
    })();
    let (mut engine, mut draft) = match setup {
        Ok(p) => {
            let _ = ready_tx.send(Ok((seq, vocab)));
            p
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let capacity = engine.capacity();
    let mut prefix_store = (prefix_cache > 0).then(|| PrefixStore::new(prefix_cache, vocab));
    let mut active: Vec<Option<InFlight>> = (0..capacity).map(|_| None).collect();
    let mut open = true; // request queue still accepting
    // per-step (slot, chunk length) plan + chunk wants, reused buffers
    let mut step_plan: Vec<(usize, usize)> = Vec::with_capacity(capacity);
    let mut wants: Vec<usize> = Vec::with_capacity(capacity);
    // tracing state: per-slot trace lengths before each step (so chunk
    // events carry their exact modeled-ns delta), the worker's position
    // on the modeled pipeline-time axis, and its prefix-cache counters
    let mut pre_lens: Vec<usize> = Vec::with_capacity(capacity);
    let mut sim_cursor_ns = 0.0f64;
    let (mut prefix_hits_w, mut prefix_lookups_w) = (0u32, 0u32);
    loop {
        // --- cancel: release slots whose client vanished ---
        // The liveness check runs every step boundary, so an abandoned
        // window stops consuming lanes within one replay of the drop.
        // Positions already replayed stay on the bill (record_sim_tokens
        // from the trace) — the chip really did the work.
        for slot in 0..capacity {
            let dead = matches!(&active[slot], Some(a) if a.alive.upgrade().is_none());
            if dead {
                let a = active[slot].take().expect("checked above");
                let costs = engine.take_trace(slot);
                if !costs.is_empty() {
                    let total = sum_costs(&costs);
                    metrics.record_sim_tokens(
                        costs.len(),
                        total.latency.critical_ns(),
                        total.energy.total_nj(),
                    );
                }
                engine.release(slot);
                if let Some(d) = draft.as_mut() {
                    d.release(slot);
                }
                metrics.record_cancellation();
                if let Some(w) = wt.as_mut() {
                    let t = w.now_us();
                    w.record(Event::at(EventKind::Cancel, a.id, wid, t).ab(a.fed as u32, 0));
                }
                drop(a); // the reply channel dies unanswered — by request
            }
        }
        // --- admit: fill free slots between token steps ---
        while open && engine.occupancy() < capacity {
            let req = if engine.occupancy() == 0 {
                // idle chip: block until work arrives (or shutdown)
                match queue.recv() {
                    Some(r) => Some(r),
                    None => {
                        open = false;
                        None
                    }
                }
            } else {
                // busy chip: opportunistic, never stalls the batch
                match queue.try_pop() {
                    TryPop::Item(r) => Some(r),
                    TryPop::Empty => break,
                    TryPop::Closed => {
                        open = false;
                        None
                    }
                }
            };
            let Some(req) = req else { break };
            if req.alive.upgrade().is_none() {
                // client gave up while queued: never occupy a slot
                metrics.record_cancellation();
                if let Some(w) = wt.as_mut() {
                    let t = w.now_us();
                    w.record(Event::at(EventKind::Cancel, req.id, wid, t));
                }
                continue;
            }
            if let Err(e) = validate_window(&req.tokens, seq, vocab) {
                metrics.record_error();
                let _ = req.resp.send(Err(e));
                continue;
            }
            let slot = engine.try_admit().expect("occupancy < capacity");
            if let Some(d) = draft.as_mut() {
                // admissions and releases are paired, so both pools have
                // identical free sets and hand out the same slot index
                let ds = d.try_admit().expect("draft pool mirrors the target pool");
                debug_assert_eq!(ds, slot, "draft slot diverged from target slot");
            }
            let window = req.tokens.len();
            // shared-prefix splice: cached K/V skip prefill, cached
            // logits answer the covered positions (bit-identical to
            // cold prefill — tests/prop_prefix_cache.rs)
            let mut out = Vec::with_capacity(window * vocab);
            let mut spliced = 0usize;
            if let Some(store) = prefix_store.as_mut() {
                if let Some(hit) = store.lookup(&req.tokens) {
                    engine.splice_kv(slot, &hit.kv, hit.positions);
                    out.extend_from_slice(&hit.logits);
                    spliced = hit.positions;
                }
                metrics.record_prefix_lookup(spliced);
            }
            if let Some(w) = wt.as_mut() {
                // the admit span IS the queue wait: submission → slot
                let now = w.now_us();
                let t0 = w.us_of(req.t0);
                w.record(
                    Event::span(EventKind::Admit, req.id, wid, t0, now)
                        .ab(slot as u32, window as u32),
                );
                if spliced > 0 {
                    w.record(
                        Event::at(EventKind::PrefixSplice, req.id, wid, now)
                            .ab(spliced as u32, 0),
                    );
                }
                if prefix_store.is_some() {
                    prefix_lookups_w += 1;
                    prefix_hits_w += (spliced > 0) as u32;
                    w.record(
                        Event::at(EventKind::PrefixHitRate, 0, wid, now)
                            .ab(prefix_hits_w, prefix_lookups_w),
                    );
                }
            }
            active[slot] = Some(InFlight {
                tokens: req.tokens,
                fed: spliced,
                spliced,
                out,
                resp: req.resp,
                alive: req.alive,
                t0: req.t0, // submission time, so queue wait is counted
                ttft_us: None,
                first_chunk: 0,
                id: req.id,
            });
        }
        if engine.occupancy() == 0 {
            if open {
                continue; // raced an invalid request; go back to recv
            }
            break; // drained and disconnected
        }
        // --- step: advance every in-flight window by one chunk ---
        // Every occupied slot wants up to `chunk` of its remaining
        // positions; the allocator floors each at one lane (no
        // starvation) and bounds the step's total lane count.
        step_plan.clear();
        wants.clear();
        for (slot, a) in active.iter().enumerate() {
            if let Some(a) = a {
                step_plan.push((slot, 0));
                let want = match draft.as_mut() {
                    // speculative chunking needs a scored prefix for the
                    // draft to continue from; the first chunk of a window
                    // prefills normally
                    Some(d) if a.fed > 0 => speculative_want(
                        d,
                        slot,
                        &a.tokens,
                        a.fed,
                        speculate_k,
                        &metrics,
                    ),
                    _ => (a.tokens.len() - a.fed).min(chunk),
                };
                wants.push(want);
            }
        }
        let alloc = allocate_chunks(&wants, lane_budget);
        for (p, &c) in step_plan.iter_mut().zip(&alloc) {
            p.1 = c;
        }
        // tracing: mark the step start and each planned slot's trace
        // length, so eviction can attribute this step's modeled ns to
        // its chunk events (one record per chunk, never per lane)
        let t_step_start = wt.as_ref().map(|w| w.now_us()).unwrap_or(0.0);
        pre_lens.clear();
        if wt.is_some() {
            pre_lens.extend(step_plan.iter().map(|&(slot, _)| engine.slot_trace(slot).len()));
        }
        {
            let groups: Vec<(usize, &[i32])> = step_plan
                .iter()
                .map(|&(slot, c)| {
                    let a = active[slot].as_ref().expect("planned slot is active");
                    (slot, &a.tokens[a.fed..a.fed + c])
                })
                .collect();
            engine.step_chunks(&groups);
        }
        let t_step_end = wt.as_ref().map(|w| w.now_us()).unwrap_or(0.0);
        metrics.record_worker_occupancy(worker, step_plan.len(), capacity);
        // sharded engine: drain the step's pipeline window into the
        // shared metrics (no-op on the mono path — zero steps recorded)
        let ps = engine.take_pipeline_stats();
        metrics.record_pipeline(
            ps.steps,
            &ps.stage_busy_ns,
            ps.span_ns,
            ps.transfer_ns,
            ps.serial_ns,
        );
        if let Some(w) = wt.as_mut() {
            w.record(
                Event::at(EventKind::Occupancy, 0, wid, t_step_end)
                    .ab(step_plan.len() as u32, capacity as u32),
            );
            w.record(
                Event::at(EventKind::QueueDepth, 0, wid, t_step_end)
                    .ab(queue.depth() as u32, 0),
            );
            // sharded engine: replay the step's stage windows onto the
            // worker's modeled sim-time axis (µs of accumulated span)
            if let Some(tl) = &ps.last {
                for sw in &tl.windows {
                    w.record(
                        Event::span(
                            EventKind::StageStep,
                            0,
                            wid,
                            (sim_cursor_ns + sw.start_ns) / 1e3,
                            (sim_cursor_ns + sw.end_ns) / 1e3,
                        )
                        .ab(sw.stage as u32, sw.microbatch as u32)
                        .sim(sw.end_ns - sw.start_ns),
                    );
                }
            }
            sim_cursor_ns += ps.span_ns;
        }
        // --- evict: finished windows reply and free their slot ---
        let mut finished: Vec<InFlight> = Vec::new();
        let mut lane = 0usize;
        let mut step_sim_ns = 0.0f64;
        for (i, &(slot, c)) in step_plan.iter().enumerate() {
            // this chunk's modeled-ns delta: the per-position costs the
            // step appended to the slot's trace (read before the done
            // branch's take_trace drains it)
            let chunk_sim_ns = if wt.is_some() {
                engine.slot_trace(slot)[pre_lens[i]..]
                    .iter()
                    .map(|p| p.latency.critical_ns())
                    .sum::<f64>()
            } else {
                0.0
            };
            step_sim_ns += chunk_sim_ns;
            let a = active[slot].as_mut().expect("stepped slot is active");
            // stream this chunk's per-position logits (flattened lane
            // order matches the step_plan group order)
            for i in 0..c {
                a.out.extend_from_slice(engine.lane_logits(lane + i));
            }
            lane += c;
            if a.ttft_us.is_none() {
                // first logits of this request now exist: TTFT. A
                // spliced prefix is answered in the same reply unit as
                // the first stepped chunk, so it counts toward the
                // TTFT phase, not the inter-token cadence.
                a.ttft_us = Some(a.t0.elapsed().as_micros() as f64);
                a.first_chunk = a.spliced + c;
            }
            // prefill counters mean *prompt-ingestion* chunks; verify
            // chunks sized by the draft (every post-first chunk when
            // speculation is on) are counted by record_speculation
            if c > 1 && (draft.is_none() || a.fed == a.spliced) {
                metrics.record_prefill_chunk(c);
            }
            if let Some(w) = wt.as_mut() {
                // classified exactly like the metrics counters above:
                // prompt-ingestion chunk, draft-sized verify round, or
                // plain decode-pace step
                let kind = if c > 1 && (draft.is_none() || a.fed == a.spliced) {
                    EventKind::PrefillChunk
                } else if draft.is_some() && a.fed > a.spliced {
                    EventKind::SpecRound
                } else {
                    EventKind::DecodeStep
                };
                w.record(
                    Event::span(kind, a.id, wid, t_step_start, t_step_end)
                        .ab(c as u32, a.fed as u32)
                        .sim(chunk_sim_ns),
                );
            }
            a.fed += c;
            if a.fed == a.tokens.len() {
                let costs = engine.take_trace(slot);
                let total = sum_costs(&costs);
                // sim_tokens counts positions replayed on the chip —
                // a spliced prefix was billed on its donor's pass
                metrics.record_sim_tokens(
                    a.tokens.len() - a.spliced,
                    total.latency.critical_ns(),
                    total.energy.total_nj(),
                );
                let total_us = a.t0.elapsed().as_micros() as f64;
                let ttft = a.ttft_us.unwrap_or(total_us);
                let tail = a.tokens.len().saturating_sub(a.first_chunk);
                let inter = if tail > 0 {
                    Some((total_us - ttft).max(0.0) / tail as f64)
                } else {
                    None
                };
                metrics.record_request_timing(ttft, inter);
                if let Some(w) = wt.as_mut() {
                    // sim_ns carries the request's modeled total — the
                    // prop test checks its chunk events sum to this
                    let t = w.now_us();
                    w.record(
                        Event::at(EventKind::Reply, a.id, wid, t)
                            .ab(
                                (a.tokens.len() - a.spliced) as u32,
                                a.tokens.len() as u32,
                            )
                            .sim(total.latency.critical_ns()),
                    );
                }
                // donate the completed window to the prefix store
                // before releasing wipes the slot's KV
                if let Some(store) = prefix_store.as_mut() {
                    store.insert(&a.tokens, engine.kv(slot), &a.out);
                }
                engine.release(slot);
                if let Some(d) = draft.as_mut() {
                    d.release(slot);
                }
                finished.push(active[slot].take().expect("finished slot"));
            }
        }
        if let Some(w) = wt.as_mut() {
            w.record(
                Event::span(EventKind::WorkerStep, 0, wid, t_step_start, t_step_end)
                    .ab(lane as u32, step_plan.len() as u32)
                    .sim(step_sim_ns),
            );
        }
        if !finished.is_empty() {
            // record before replying so snapshots taken by a caller
            // right after its reply see this completion group (same
            // invariant as the PJRT worker); per-request latencies keep
            // the percentiles honest under ragged admission times
            let latencies: Vec<f64> = finished
                .iter()
                .map(|f| f.t0.elapsed().as_micros() as f64)
                .collect();
            metrics.record_completions(&latencies);
            for f in finished {
                let _ = f.resp.send(Ok(f.out));
            }
        }
    }
}

impl InferenceServer {
    /// Start the worker pool (loads + compiles the backend eagerly).
    ///
    /// The PJRT client is not `Send`, so the backend is constructed
    /// *inside* the worker thread; readiness (or the startup error) is
    /// reported back through a one-shot channel. The CIM-sim backend
    /// spawns `workers` copies of the continuous-batching loop — each
    /// its own programmed chip — sharing one request queue; startup
    /// fails (and joins whatever did start) if any worker fails to
    /// program its chip.
    pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
        let metrics = Arc::new(Metrics::new());
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize)>>();
        let policy = cfg.policy.clone();
        let trace = match &cfg.backend {
            Backend::CimSim(sc) => sc.trace.clone(),
            Backend::Pjrt => None,
        };
        let (tx, handles) = match cfg.backend {
            Backend::Pjrt => {
                let dir = cfg.artifacts_dir.clone();
                let metrics_w = metrics.clone();
                let (tx, rx) = channel::<Request>();
                let h = std::thread::spawn(move || {
                    run_pjrt_worker(dir, policy, metrics_w, rx, ready_tx)
                });
                (Submitter::Channel(tx), vec![h])
            }
            Backend::CimSim(sim_cfg) => {
                let queue = Arc::new(RequestQueue::new());
                let w = sim_cfg.workers.max(1);
                let handles = (0..w)
                    .map(|id| {
                        let cfg = sim_cfg.clone();
                        let policy = policy.clone();
                        let metrics = metrics.clone();
                        let queue = queue.clone();
                        let ready_tx = ready_tx.clone();
                        std::thread::spawn(move || {
                            run_cimsim_worker(id, cfg, policy, metrics, queue, ready_tx)
                        })
                    })
                    .collect();
                (Submitter::Queue(queue), handles)
            }
        };
        drop(ready_tx); // workers hold their clones

        // collect one readiness report per spawned worker; on any
        // failure, close the queue and join the survivors before
        // surfacing the first error
        let mut shape: Option<(usize, usize)> = None;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..handles.len() {
            match ready_rx.recv() {
                Ok(Ok(s)) => shape = Some(s),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err
                        .or_else(|| Some(anyhow!("server worker died during startup")))
                }
            }
        }
        if let Some(e) = first_err {
            tx.close();
            drop(tx);
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        let (seq, vocab) = shape.expect("every worker reported ready");
        Ok(InferenceServer {
            tx: Some(tx),
            workers: handles,
            metrics,
            seq,
            vocab,
            trace,
        })
    }

    /// Requests currently waiting in the shared dispatch queue (0 for
    /// the PJRT channel backend, whose mpsc depth is unobservable).
    pub fn queue_depth(&self) -> usize {
        self.tx.as_ref().map(Submitter::depth).unwrap_or(0)
    }

    /// Submit a request without blocking on the reply: returns a
    /// [`PendingResponse`] to `wait` on. Dropping the handle cancels
    /// the request (the worker releases its slot at the next step
    /// boundary and counts a cancellation).
    pub fn submit(&self, tokens: Vec<i32>) -> Result<PendingResponse> {
        let (rtx, rrx) = channel();
        let alive = Arc::new(());
        let t0 = Instant::now();
        // tracing: assign the request id and mark the enqueue instant
        // (the worker's admit span will start from the same t0)
        let mut id = 0u64;
        if let Some(t) = &self.trace {
            id = t.next_request_id();
            let ts = t.us_of(t0);
            t.record(Event::at(EventKind::Enqueue, id, 0, ts).ab(tokens.len() as u32, 0));
        }
        let sub = self.tx.as_ref().ok_or_else(|| anyhow!("server stopped"))?;
        sub.send(Request {
            tokens,
            resp: rtx,
            alive: Arc::downgrade(&alive),
            t0,
            id,
        })?;
        if let Some(t) = &self.trace {
            let ts = t.now_us();
            t.record(Event::at(EventKind::QueueDepth, 0, 0, ts).ab(sub.depth() as u32, 0));
        }
        Ok(PendingResponse {
            rx: rrx,
            _alive: alive,
        })
    }

    /// Blocking inference: returns per-position logits (window len *
    /// vocab; the CIM-sim backend accepts ragged windows of 1..=seq).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Vec<f32>> {
        self.submit(tokens)?.wait()
    }

    /// Graceful shutdown: close the queue and join every worker
    /// (queued requests still drain).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close(); // Channel closes on the drop below
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bucket_table() -> Vec<Bucket> {
        [1usize, 2, 4, 8]
            .iter()
            .map(|&b| (b, format!("tiny_lm_b{b}"), 8, 16))
            .collect()
    }

    #[test]
    fn select_artifact_picks_smallest_fitting_bucket() {
        let buckets = bucket_table();
        let (bucket, artifact) = select_artifact(&buckets, 3).unwrap();
        assert_eq!(bucket, 4);
        assert_eq!(artifact, "tiny_lm_b4");
        let (bucket, artifact) = select_artifact(&buckets, 1).unwrap();
        assert_eq!(bucket, 1);
        assert_eq!(artifact, "tiny_lm_b1");
    }

    #[test]
    fn select_artifact_falls_back_to_largest_bucket() {
        // an oversize chunk takes the largest compiled batch; the
        // worker then splits the chunk and loops
        let buckets = bucket_table();
        let (bucket, artifact) = select_artifact(&buckets, 100).unwrap();
        assert_eq!(bucket, 8);
        assert_eq!(artifact, "tiny_lm_b8");
    }

    #[test]
    fn select_artifact_on_empty_table_is_an_error_not_a_panic() {
        // regression: this path used to `unwrap` a `sizes.last()` of an
        // empty table, killing the worker thread with every caller's
        // reply channel still open
        let err = select_artifact(&[], 5).unwrap_err();
        assert!(
            err.to_string().contains("no compiled batch buckets"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn failed_chunk_round_trips_error_replies_and_keeps_channels_alive() {
        // every request of a failed chunk must receive a structured
        // error reply (no hung `recv`, no panic), and the failure must
        // land in the error counter exactly once per chunk
        let metrics = Metrics::new();
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        let mut tokens_alive = Vec::new();
        for _ in 0..3 {
            let (rtx, rrx) = channel();
            let alive = Arc::new(());
            reqs.push(Request {
                tokens: vec![0, 1, 2],
                resp: rtx,
                alive: Arc::downgrade(&alive),
                t0: Instant::now(),
                id: 0,
            });
            tokens_alive.push(alive);
            rxs.push(rrx);
        }
        let err = select_artifact(&[], reqs.len()).unwrap_err();
        fail_chunk(&reqs, &err, &metrics);
        for rrx in rxs {
            let reply = rrx.recv().expect("reply channel must stay alive");
            let msg = reply.expect_err("chunk failed, reply must be Err").to_string();
            assert!(
                msg.contains("batch scheduling failed"),
                "unexpected reply: {msg}"
            );
            assert!(msg.contains("no compiled batch buckets"), "cause lost: {msg}");
        }
        assert_eq!(metrics.snapshot().errors, 1);
    }

    #[test]
    fn request_queue_drains_after_close_and_rejects_new_pushes() {
        let q = RequestQueue::new();
        let (rtx, _rrx) = channel();
        let alive = Arc::new(());
        let req = Request {
            tokens: vec![1],
            resp: rtx,
            alive: Arc::downgrade(&alive),
            t0: Instant::now(),
            id: 0,
        };
        q.push(req).expect("open queue accepts");
        q.close();
        // queued work still drains after close (graceful shutdown)…
        assert!(matches!(q.try_pop(), TryPop::Item(_)));
        // …then the queue reports closed, and new pushes bounce
        assert!(matches!(q.try_pop(), TryPop::Closed));
        let (rtx, _rrx) = channel();
        let rejected = Request {
            tokens: vec![2],
            resp: rtx,
            alive: Arc::downgrade(&alive),
            t0: Instant::now(),
            id: 0,
        };
        assert!(q.push(rejected).is_err());
        assert!(q.recv().is_none(), "blocking recv wakes on closed+empty");
    }

    /// Regression (ISSUE 8 satellite): a request whose client vanished
    /// must be counted as a cancellation and never hold chip work —
    /// dropped-at-queue requests are skipped at admission, and the live
    /// neighbour's reply is unaffected. Drives `run_cimsim_worker`
    /// directly with a pre-loaded, closed queue.
    #[test]
    fn dead_clients_are_cancelled_not_served() {
        let queue = Arc::new(RequestQueue::new());
        let metrics = Arc::new(Metrics::new());
        let (ready_tx, ready_rx) = channel();

        // dead request: the strong end of the liveness token is dropped
        // before the worker ever runs (client gave up while queued)
        let (dead_tx, dead_rx) = channel();
        let dead_alive = Arc::new(());
        queue
            .push(Request {
                tokens: vec![1, 2, 3, 4],
                resp: dead_tx,
                alive: Arc::downgrade(&dead_alive),
                t0: Instant::now(),
                id: 0,
            })
            .unwrap();
        drop(dead_alive);
        drop(dead_rx);

        // live request: token held for the duration
        let (live_tx, live_rx) = channel();
        let live_alive = Arc::new(());
        let live_window = vec![5i32, 6, 7];
        queue
            .push(Request {
                tokens: live_window.clone(),
                resp: live_tx,
                alive: Arc::downgrade(&live_alive),
                t0: Instant::now(),
                id: 0,
            })
            .unwrap();
        queue.close(); // worker drains both and exits

        let cfg = CimSimConfig::default();
        run_cimsim_worker(
            0,
            cfg,
            BatchPolicy::default(),
            metrics.clone(),
            queue,
            ready_tx,
        );
        assert!(ready_rx.recv().unwrap().is_ok());

        let logits = live_rx
            .recv()
            .expect("live client must get a reply")
            .expect("live request succeeds");
        assert_eq!(logits.len(), live_window.len() * ModelConfig::tiny().vocab);
        let snap = metrics.snapshot();
        assert_eq!(snap.cancellations, 1, "dead client counted once");
        assert_eq!(
            snap.sim_tokens,
            live_window.len() as u64,
            "no chip work replayed for the dead request"
        );
        assert_eq!(snap.requests, 1, "only the live request completed");
        drop(live_alive);
    }
}
