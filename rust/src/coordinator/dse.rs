//! Automated design-space exploration: given a resource envelope (array
//! budget, ADCs per array) pick the best mapping strategy — the
//! "automated framework" closing step of Fig. 2a, extended with the
//! §III-B1 swap-overhead model for constrained systems.
//!
//! [`explore`] sweeps the analytic envelope (strategy × ADC count ×
//! array budget). [`explore_measured`] adds the accuracy axis: it sweeps
//! strategy × ADC resolution cap × programming-noise sigma, pricing each
//! point with the `scheduler::timing` cost model at the capped
//! resolution and *measuring* its token-level divergence by replaying a
//! teacher-forced window through a noise/ADC-aware functional chip
//! ([`crate::cim::AnalogMode`]) against the exact one — the
//! accuracy-vs-energy-vs-latency frontier the `dse` CLI subcommand
//! writes to `BENCH_dse.json`.

use crate::cim::{adc, AnalogMode, CimParams, PcmNoise};
use crate::mapping::constrained::{constrained_token_latency_ns, swap_overhead, WriteCosts};
use crate::mapping::{map_model, map_ops, Strategy};
use crate::model::ModelConfig;
use crate::scheduler::{adc_bits_for, compile_plan};
use crate::sim::decode::{DecodeEngine, DecodeModel};
use crate::sim::divergence::{compare_logits, Divergence};

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub strategy: Strategy,
    pub adcs_per_array: usize,
    pub array_budget: Option<usize>,
    pub fits_budget: bool,
    /// Per-token latency incl. swap overhead (ns).
    pub token_latency_ns: f64,
    /// Full-sequence energy (mJ), swap energy included.
    pub energy_mj: f64,
    pub arrays: usize,
    pub adc_bits: u32,
}

/// Exhaustive sweep over strategies x ADC counts under a budget.
pub fn explore(
    cfg: &ModelConfig,
    adc_counts: &[usize],
    array_budget: Option<usize>,
    costs: &WriteCosts,
) -> Vec<DsePoint> {
    let mut out = Vec::new();
    for &adcs in adc_counts {
        let params = CimParams::default().with_adcs_per_array(adcs);
        for strategy in Strategy::all() {
            let mapping = map_model(cfg, &params, strategy);
            let budget = array_budget.unwrap_or(usize::MAX);
            let swap = swap_overhead(&mapping, budget, costs);
            let token_latency_ns =
                constrained_token_latency_ns(&mapping, cfg, &params, budget, costs);
            let base =
                crate::scheduler::timing::cost_report_for_mapping(cfg, &mapping, &params);
            let energy_mj = base.energy_mj()
                + swap.swap_energy_nj * cfg.seq as f64 / 1e6;
            out.push(DsePoint {
                strategy,
                adcs_per_array: adcs,
                array_budget,
                fits_budget: swap.fits,
                token_latency_ns,
                energy_mj,
                arrays: mapping.arrays,
                adc_bits: base.adc_bits,
            });
        }
    }
    out
}

/// Best point by latency; ties broken by energy.
pub fn best(points: &[DsePoint]) -> Option<&DsePoint> {
    points.iter().min_by(|a, b| {
        (a.token_latency_ns, a.energy_mj)
            .partial_cmp(&(b.token_latency_ns, b.energy_mj))
            .unwrap()
    })
}

/// One point of the measured accuracy-vs-energy-vs-latency frontier:
/// analytic per-token cost with the ADC conversion components rescaled
/// to the capped resolution (SAR conversion time and energy are linear
/// in bits), plus the *measured* token-level divergence of a
/// teacher-forced replay on a noise/ADC-aware chip vs the exact one.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    pub strategy: Strategy,
    /// ADC resolution cap wired into the replay (`None` = uncapped).
    pub adc_bits: Option<u32>,
    /// Resolution the cost model prices conversions at: the strategy's
    /// natural §IV-B policy bits ([`adc_bits_for`]), clamped to the cap.
    pub effective_bits: u32,
    /// Programming (write) noise sigma swept into [`PcmNoise`].
    pub write_sigma: f64,
    /// Analytic per-token critical-path latency (ns) at `effective_bits`.
    pub token_latency_ns: f64,
    /// Analytic per-token energy (nJ) at `effective_bits`.
    pub energy_nj: f64,
    /// Fraction of one full-model replay's conversions the cap actually
    /// re-quantizes (`required_bits(conv_depth) > cap`), from the
    /// compiled plan's conversion-depth histogram.
    pub quantized_frac: f64,
    /// Measured divergence from the exact engine over the token window.
    pub divergence: Divergence,
}

impl FrontierPoint {
    /// Whether the point's analog settings are ideal — no programming
    /// noise and no conversion below its exact resolution. Such points
    /// are bit-identical to the exact path by construction, so they must
    /// measure zero divergence; the `dse` CLI's `--gate-ideal` flag (and
    /// CI) asserts exactly that.
    pub fn is_ideal(&self) -> bool {
        self.write_sigma == 0.0 && self.quantized_frac == 0.0
    }
}

/// Sweep strategy × ADC resolution cap × write-noise sigma on a
/// synthesized decoder, measuring each point's token-level divergence
/// against the exact engine over the teacher-forced `tokens` window.
///
/// Latency/energy come from the analytic per-token cost model with the
/// ADC components scaled by `effective_bits / natural_bits` — exact
/// under the linear SAR conversion model and deliberately *not* done by
/// shrinking `CimParams::adc_ref_bits`, which would silently rescale the
/// reference pricing and disable the replay's quantization gate at the
/// same time. Noise is seeded per `noise_seed`, so the whole frontier is
/// deterministic; drift is left off (the `decode` CLI exposes it
/// separately) to keep sigma the only accuracy knob besides the cap.
pub fn explore_measured(
    cfg: &ModelConfig,
    params: &CimParams,
    model_seed: u64,
    noise_seed: u64,
    adc_caps: &[Option<u32>],
    sigmas: &[f64],
    tokens: &[i32],
) -> Vec<FrontierPoint> {
    assert!(!tokens.is_empty(), "need a non-empty scoring window");
    let mut out = Vec::new();
    for strategy in Strategy::all() {
        let model = DecodeModel::synth(cfg.clone(), model_seed);
        let mapping = map_ops(cfg, &model.ops, params, strategy);
        let hist = compile_plan(&mapping).conversion_depth_histogram();
        let total_convs: usize = hist.iter().sum();
        let natural = adc_bits_for(params, strategy, mapping.b);
        let per_token = crate::scheduler::timing::per_token_cost(cfg, &mapping, params);
        let mut exact = DecodeEngine::on_chip(model, params.clone(), strategy);
        let (exact_logits, _) = exact.score(tokens);
        drop(exact);
        for &cap in adc_caps {
            let effective = cap.map_or(natural, |c| c.clamp(1, natural));
            let ratio = effective as f64 / natural as f64;
            let token_latency_ns =
                per_token.latency.critical_ns() - per_token.latency.adc_ns * (1.0 - ratio);
            let energy_nj =
                per_token.energy.total_nj() - per_token.energy.adc_nj * (1.0 - ratio);
            let quantized: usize = match cap {
                None => 0,
                Some(bits) => hist
                    .iter()
                    .enumerate()
                    .filter(|&(depth, _)| adc::required_bits(params, depth) > bits)
                    .map(|(_, &cols)| cols)
                    .sum(),
            };
            let quantized_frac = quantized as f64 / total_convs.max(1) as f64;
            for &sigma in sigmas {
                let mode = AnalogMode {
                    noise: PcmNoise {
                        write_sigma: sigma,
                        drift_nu: 0.0,
                        drift_time_ratio: 1.0,
                    },
                    adc_bits: cap,
                    seed: noise_seed,
                };
                let model = DecodeModel::synth(cfg.clone(), model_seed);
                let mut analog =
                    DecodeEngine::on_chip_analog(model, params.clone(), strategy, Some(&mode));
                let (analog_logits, _) = analog.score(tokens);
                out.push(FrontierPoint {
                    strategy,
                    adc_bits: cap,
                    effective_bits: effective,
                    write_sigma: sigma,
                    token_latency_ns,
                    energy_nj,
                    quantized_frac,
                    divergence: compare_logits(&exact_logits, &analog_logits, tokens, cfg.vocab),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_low_adc_prefers_densemap() {
        let pts = explore(
            &ModelConfig::bert_large(),
            &[1],
            None,
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::DenseMap);
    }

    #[test]
    fn unconstrained_high_adc_prefers_sparsemap() {
        let pts = explore(
            &ModelConfig::bert_large(),
            &[32],
            None,
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::SparseMap);
    }

    #[test]
    fn tight_budget_forces_densemap_even_at_high_adc() {
        // under 512 arrays only DenseMap fits -> swap overhead buries the
        // others despite their better per-pass latency
        let pts = explore(
            &ModelConfig::bert_large(),
            &[32],
            Some(512),
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::DenseMap);
        assert!(b.fits_budget);
        let sparse = pts
            .iter()
            .find(|p| p.strategy == Strategy::SparseMap)
            .unwrap();
        assert!(!sparse.fits_budget);
        assert!(sparse.token_latency_ns > 10.0 * b.token_latency_ns);
    }

    #[test]
    fn explore_covers_grid() {
        let pts = explore(
            &ModelConfig::tiny(),
            &[1, 8],
            None,
            &WriteCosts::default(),
        );
        assert_eq!(pts.len(), 6);
        assert!(pts.iter().all(|p| p.token_latency_ns > 0.0));
    }

    #[test]
    fn explore_flags_infeasible_points_without_dropping_them() {
        // a budget only DenseMap fits must not shrink the grid: every
        // strategy x ADC-count point stays, just marked infeasible
        let pts = explore(
            &ModelConfig::bert_large(),
            &[1, 32],
            Some(512),
            &WriteCosts::default(),
        );
        assert_eq!(pts.len(), 2 * Strategy::all().len());
        assert!(pts.iter().any(|p| !p.fits_budget), "budget never binds");
        assert!(pts.iter().any(|p| p.fits_budget), "budget kills everything");
        for p in &pts {
            assert_eq!(p.array_budget, Some(512));
            assert!(p.token_latency_ns > 0.0, "{p:?} dropped from pricing");
        }
    }

    #[test]
    fn measured_frontier_covers_grid_and_ideal_points_are_exact() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let caps = [None, Some(2)];
        let sigmas = [0.0, 0.05];
        let tokens = [11i32, 48, 85, 122];
        let pts = explore_measured(&cfg, &params, 3, 17, &caps, &sigmas, &tokens);
        assert_eq!(
            pts.len(),
            Strategy::all().len() * caps.len() * sigmas.len()
        );
        for p in &pts {
            assert!((0.0..=1.0).contains(&p.quantized_frac), "{p:?}");
            assert!(p.token_latency_ns > 0.0 && p.energy_nj > 0.0, "{p:?}");
            assert_eq!(p.divergence.positions, tokens.len(), "{p:?}");
            if p.adc_bits.is_none() {
                assert_eq!(p.quantized_frac, 0.0, "uncapped point quantizes");
            }
            if p.is_ideal() {
                assert!(
                    p.divergence.is_exact(),
                    "ideal point diverged: {p:?}"
                );
            }
        }
        // a 2-bit cap sits below every strategy's exact-conversion
        // resolution on tiny (8-deep Monarch bitlines need 3 bits), so
        // it must both re-quantize conversions and measurably diverge
        for p in pts.iter().filter(|p| p.adc_bits == Some(2)) {
            assert!(p.quantized_frac > 0.0, "{p:?}");
            assert!(!p.divergence.is_exact(), "{p:?}");
        }
        // noise alone must diverge too
        for p in pts.iter().filter(|p| p.write_sigma > 0.0) {
            assert!(p.divergence.max_abs_logit_err > 0.0, "{p:?}");
        }
    }

    #[test]
    fn measured_frontier_prices_caps_cheaper_never_slower() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let tokens = [5i32, 9];
        let pts =
            explore_measured(&cfg, &params, 3, 17, &[None, Some(2)], &[0.0], &tokens);
        for s in Strategy::all() {
            let full = pts
                .iter()
                .find(|p| p.strategy == s && p.adc_bits.is_none())
                .unwrap();
            let capped = pts
                .iter()
                .find(|p| p.strategy == s && p.adc_bits == Some(2))
                .unwrap();
            assert_eq!(full.effective_bits, adc_bits_for(&params, s, 8));
            assert_eq!(capped.effective_bits, 2, "{s:?}");
            assert!(capped.energy_nj < full.energy_nj, "{s:?} cap not cheaper");
            assert!(
                capped.token_latency_ns <= full.token_latency_ns,
                "{s:?} cap slower"
            );
        }
    }
}
