//! Automated design-space exploration: given a resource envelope (array
//! budget, ADCs per array) pick the best mapping strategy — the
//! "automated framework" closing step of Fig. 2a, extended with the
//! §III-B1 swap-overhead model for constrained systems.

use crate::cim::CimParams;
use crate::mapping::constrained::{constrained_token_latency_ns, swap_overhead, WriteCosts};
use crate::mapping::{map_model, Strategy};
use crate::model::ModelConfig;

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub strategy: Strategy,
    pub adcs_per_array: usize,
    pub array_budget: Option<usize>,
    pub fits_budget: bool,
    /// Per-token latency incl. swap overhead (ns).
    pub token_latency_ns: f64,
    /// Full-sequence energy (mJ), swap energy included.
    pub energy_mj: f64,
    pub arrays: usize,
    pub adc_bits: u32,
}

/// Exhaustive sweep over strategies x ADC counts under a budget.
pub fn explore(
    cfg: &ModelConfig,
    adc_counts: &[usize],
    array_budget: Option<usize>,
    costs: &WriteCosts,
) -> Vec<DsePoint> {
    let mut out = Vec::new();
    for &adcs in adc_counts {
        let params = CimParams::default().with_adcs_per_array(adcs);
        for strategy in Strategy::all() {
            let mapping = map_model(cfg, &params, strategy);
            let budget = array_budget.unwrap_or(usize::MAX);
            let swap = swap_overhead(&mapping, budget, costs);
            let token_latency_ns =
                constrained_token_latency_ns(&mapping, cfg, &params, budget, costs);
            let base =
                crate::scheduler::timing::cost_report_for_mapping(cfg, &mapping, &params);
            let energy_mj = base.energy_mj()
                + swap.swap_energy_nj * cfg.seq as f64 / 1e6;
            out.push(DsePoint {
                strategy,
                adcs_per_array: adcs,
                array_budget,
                fits_budget: swap.fits,
                token_latency_ns,
                energy_mj,
                arrays: mapping.arrays,
                adc_bits: base.adc_bits,
            });
        }
    }
    out
}

/// Best point by latency; ties broken by energy.
pub fn best(points: &[DsePoint]) -> Option<&DsePoint> {
    points.iter().min_by(|a, b| {
        (a.token_latency_ns, a.energy_mj)
            .partial_cmp(&(b.token_latency_ns, b.energy_mj))
            .unwrap()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_low_adc_prefers_densemap() {
        let pts = explore(
            &ModelConfig::bert_large(),
            &[1],
            None,
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::DenseMap);
    }

    #[test]
    fn unconstrained_high_adc_prefers_sparsemap() {
        let pts = explore(
            &ModelConfig::bert_large(),
            &[32],
            None,
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::SparseMap);
    }

    #[test]
    fn tight_budget_forces_densemap_even_at_high_adc() {
        // under 512 arrays only DenseMap fits -> swap overhead buries the
        // others despite their better per-pass latency
        let pts = explore(
            &ModelConfig::bert_large(),
            &[32],
            Some(512),
            &WriteCosts::default(),
        );
        let b = best(&pts).unwrap();
        assert_eq!(b.strategy, Strategy::DenseMap);
        assert!(b.fits_budget);
        let sparse = pts
            .iter()
            .find(|p| p.strategy == Strategy::SparseMap)
            .unwrap();
        assert!(!sparse.fits_budget);
        assert!(sparse.token_latency_ns > 10.0 * b.token_latency_ns);
    }

    #[test]
    fn explore_covers_grid() {
        let pts = explore(
            &ModelConfig::tiny(),
            &[1, 8],
            None,
            &WriteCosts::default(),
        );
        assert_eq!(pts.len(), 6);
        assert!(pts.iter().all(|p| p.token_latency_ns > 0.0));
    }
}
