//! End-to-end framework driver (paper Fig. 2a): pre-trained dense
//! model -> D2S transformation -> CIM mapping -> scheduling -> cost
//! simulation, with the Fig. 2b/6/7 quantities collected along the way.
//!
//! Formerly `coordinator/pipeline.rs` — renamed so "pipeline" is free
//! for the serving-side layer-sharded pipeline (`sim::shard`). The
//! public names (`run_pipeline`, `PipelineConfig`, `PipelineResult`)
//! keep their Fig. 2a meaning and are re-exported from
//! [`crate::coordinator`] unchanged.

use crate::cim::CimParams;
use crate::mapping::stats::MappingStats;
use crate::mapping::{map_model, ModelMapping, Strategy};
use crate::model::{count_report, CountReport, ModelConfig};
use crate::monarch::project_with_report;
use crate::scheduler::timing::{cost_report_for_mapping, CostReport};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub model: ModelConfig,
    pub strategy: Strategy,
    pub cim: CimParams,
    /// Sample a synthetic dense weight and run the numeric D2S projection
    /// on it (adds the Frobenius error to the result). Scaled-down for
    /// large d_model by projecting one representative d x d weight.
    pub d2s_numeric_check: bool,
    pub seed: u64,
}

impl PipelineConfig {
    pub fn new(model: ModelConfig, strategy: Strategy) -> Self {
        Self {
            model,
            strategy,
            cim: CimParams::default(),
            d2s_numeric_check: false,
            seed: 2025,
        }
    }
}

/// Everything the pipeline produces.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub counts: CountReport,
    pub mapping: ModelMapping,
    pub mapping_stats: MappingStats,
    pub cost: CostReport,
    /// Relative Frobenius error of the sampled D2S projection (if run).
    pub d2s_rel_error: Option<f64>,
}

/// Run the full framework pipeline for one (model, strategy) pair.
pub fn run_pipeline(cfg: &PipelineConfig) -> PipelineResult {
    // 1) accounting (Fig. 2b)
    let counts = count_report(&cfg.model);

    // 2) optional numeric D2S on a synthetic representative weight
    let d2s_rel_error = if cfg.d2s_numeric_check {
        let d = cfg.model.d_model;
        let mut rng = Pcg32::new(cfg.seed);
        // near-Monarch synthetic weight: Monarch + small noise, the
        // regime dense-to-sparse fine-tuning targets
        let b = cfg.model.monarch_b();
        let base = crate::monarch::MonarchMatrix::randn(b, &mut rng)
            .to_dense()
            .scale(1.0 / b as f32);
        let noise = Matrix::randn(d, d, &mut rng).scale(0.02);
        let w = base.add(&noise);
        let (_, rep) = project_with_report(&w);
        Some(rep.rel_error)
    } else {
        None
    };

    // 3) mapping (Fig. 6)
    let mapping = map_model(&cfg.model, &cfg.cim, cfg.strategy);
    let mapping_stats = MappingStats::from_mapping(&mapping);

    // 4) scheduling + cost model (Fig. 7/8)
    let cost = cost_report_for_mapping(&cfg.model, &mapping, &cfg.cim);

    PipelineResult {
        counts,
        mapping,
        mapping_stats,
        cost,
        d2s_rel_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_produces_consistent_artifacts() {
        let cfg = PipelineConfig::new(ModelConfig::bert_large(), Strategy::DenseMap);
        let r = run_pipeline(&cfg);
        assert_eq!(r.mapping.strategy, Strategy::DenseMap);
        assert_eq!(r.mapping_stats.arrays, r.mapping.arrays);
        assert!(r.cost.latency_ms() > 0.0);
        assert!(r.counts.para_param_reduction() > 10.0);
        assert!(r.d2s_rel_error.is_none());
    }

    #[test]
    fn pipeline_numeric_d2s_small_model() {
        let mut cfg = PipelineConfig::new(ModelConfig::tiny(), Strategy::SparseMap);
        cfg.d2s_numeric_check = true;
        let r = run_pipeline(&cfg);
        let err = r.d2s_rel_error.unwrap();
        // near-Monarch input must project with small error
        assert!(err < 0.25, "d2s error {err}");
    }

    #[test]
    fn strategies_ordered_by_arrays() {
        let mk = |s| {
            run_pipeline(&PipelineConfig::new(ModelConfig::gpt2_medium(), s))
                .mapping
                .arrays
        };
        let lin = mk(Strategy::Linear);
        let sp = mk(Strategy::SparseMap);
        let de = mk(Strategy::DenseMap);
        assert!(lin > sp && sp > de, "{lin} > {sp} > {de} violated");
    }
}
