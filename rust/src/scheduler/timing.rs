//! Latency/energy model over a mapped model — produces Fig. 7 and Fig. 8.
//!
//! Execution semantics (DESIGN.md §5): inference proceeds token by token
//! (the memory-bound decode regime the paper targets); per token, layers
//! execute sequentially and each layer's parameterized matmuls execute in
//! dependency *slots* — `[q,k,v] -> [o] -> [ffn1] -> [ffn2]` (plus the
//! cross-attention group for decoders). Ops inside a slot run on
//! disjoint arrays and hence in parallel.
//!
//! Per-op per-token time:
//! * Linear: one analog pass + m conversions at 8 b through the shared
//!   ADCs, plus a shift-add tree over column partitions.
//! * SparseMap: the two Monarch stages live in different arrays and
//!   pipeline across the token stream -> one stage time at 5 b.
//! * DenseMap: stages are co-resident (paired diagonals), so the second
//!   stage partially serializes behind the first: `(1 + sigma)` stage
//!   time at 3 b, with usable ADCs capped at the lane count (block-
//!   granular rotation-pair routing). `sigma = 0.5` is the one
//!   calibrated constant in the model; everything else is Table I.
//!
//! Energy per op: analog pass energy per array pass (DAC/driver-
//! dominated, so per-pass constant), ADC conversion energy linear in
//! bits, plus DPU/communication events. The paper attributes the energy
//! gains "primarily to the low-precision ADCs" (§IV-B) — that is exactly
//! the structure here.

use crate::cim::{adc, Cost, Energy, Latency};
use crate::cim::CimParams;
use crate::mapping::{ModelMapping, Strategy};
use crate::model::ModelConfig;

/// DenseMap second-stage serialization residue (co-resident L/R lanes).
pub const DENSE_STAGE_SERIALIZATION: f64 = 0.5;

/// Per-token, per-layer and whole-inference cost report.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub model: String,
    pub strategy: Strategy,
    pub adcs_per_array: usize,
    pub adc_bits: u32,
    /// Parameterized-matmul path cost for ONE token through all layers.
    pub per_token: Cost,
    /// Full-sequence cost (seq tokens, decode-style streaming).
    pub total: Cost,
    pub seq: usize,
}

impl CostReport {
    /// Critical-path latency (analog + ADC stream; comm/DPU pipelined).
    pub fn latency_ms(&self) -> f64 {
        self.total.latency.critical_ns() / 1e6
    }

    pub fn energy_mj(&self) -> f64 {
        self.total.energy.total_nj() / 1e6
    }
}

/// Dependency slots of one transformer layer's parameterized matmuls.
/// Returns groups of op indices (into `mapping.ops`) that run in
/// parallel; groups execute sequentially. Public: the per-token command
/// stream (`scheduler::token_commands`) and the decode engine replay the
/// same slot order.
pub fn layer_slots(mapping: &ModelMapping, layer: usize) -> Vec<Vec<usize>> {
    let mut qkv = Vec::new();
    let mut wo = Vec::new();
    let mut xqkv = Vec::new();
    let mut xwo = Vec::new();
    let mut ffn1 = Vec::new();
    let mut ffn2 = Vec::new();
    for (i, op) in mapping.ops.iter().enumerate() {
        if op.layer != layer {
            continue;
        }
        let n = &op.name;
        let cross = n.starts_with("xdec");
        let bucket = if n.ends_with(".wq") || n.ends_with(".wk") || n.ends_with(".wv") {
            if cross { &mut xqkv } else { &mut qkv }
        } else if n.ends_with(".wo") {
            if cross { &mut xwo } else { &mut wo }
        } else if n.ends_with(".ffn1") {
            &mut ffn1
        } else if n.ends_with(".ffn2") {
            &mut ffn2
        } else {
            continue;
        };
        bucket.push(i);
    }
    [qkv, wo, xqkv, xwo, ffn1, ffn2]
        .into_iter()
        .filter(|g| !g.is_empty())
        .collect()
}

/// Latency+energy of one op for one token.
fn op_cost(
    mapping: &ModelMapping,
    params: &CimParams,
    op_idx: usize,
) -> Cost {
    let op = &mapping.ops[op_idx];
    let strategy = mapping.strategy;
    let b = if mapping.b == 0 { mapping.m } else { mapping.b };
    let bits = super::adc_bits_for(params, strategy, mapping.b);
    let adcs = super::usable_adcs(params, strategy, mapping.b);
    let t_conv = adc::t_conversion_ns(params, bits);
    let e_conv = adc::e_conversion_nj(params, bits);
    let _ = b;

    // conversions per array per token (one per used output column)
    let convs = op.convs_per_array.max(1);
    let conv_time = (convs as f64 / adcs as f64).ceil() * t_conv;
    let drive = params.t_drive_ns();

    let (analog_ns, adc_ns, passes) = match strategy {
        Strategy::Linear => (drive, conv_time, op.stage_arrays as f64),
        Strategy::SparseMap => {
            // two stages pipelined across the token stream
            (drive, conv_time, (op.stages * op.stage_arrays) as f64)
        }
        Strategy::DenseMap => {
            let serial = 1.0 + DENSE_STAGE_SERIALIZATION;
            (
                2.0 * drive * op.analog_phases as f64,
                conv_time * serial * op.analog_phases as f64,
                (op.stages * op.stage_arrays * op.analog_phases) as f64,
            )
        }
    };

    // shift-add tree over partial sums (column partitions / col tiles)
    let add_depth = if op.partial_adds > 0 {
        ((op.partial_adds + 1) as f64).log2().ceil()
    } else {
        0.0
    };
    let dpu_ns = add_depth * params.t_add_ns;
    let dpu_nj = op.partial_adds as f64 * params.e_shift_add_nj;

    // inter-stage / gather communication events
    let comm_events = match strategy {
        Strategy::Linear => 1.0,
        _ => 2.0, // R -> L and L -> out
    };

    // analog pass energy: per-pass constant (driver dominated)
    let analog_nj = passes * params.e_pass_nj(1.0);
    let adc_nj = passes * convs as f64 * e_conv;

    Cost {
        latency: Latency {
            analog_ns,
            adc_ns,
            comm_ns: comm_events * params.t_comm_ns,
            dpu_ns,
            mha_ns: 0.0,
        },
        energy: Energy {
            analog_nj,
            adc_nj,
            comm_nj: comm_events * params.e_comm_nj,
            dpu_nj,
            mha_nj: 0.0,
        },
    }
}

/// Per-layer digital (DPU) cost shared by all strategies: 2 LayerNorms,
/// GeLU, 2 residual adds per token (Table I rows 4-5).
fn layer_dpu_cost(params: &CimParams) -> Cost {
    Cost {
        latency: Latency {
            dpu_ns: 2.0 * params.t_layernorm_ns
                + params.t_gelu_ns
                + 2.0 * params.t_add_ns,
            ..Default::default()
        },
        energy: Energy {
            dpu_nj: 2.0 * params.e_layernorm_nj
                + params.e_gelu_nj
                + 2.0 * params.e_add_nj,
            ..Default::default()
        },
    }
}

/// Cost of one token through all layers' parameterized matmuls.
pub fn per_token_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
) -> Cost {
    let mut total = Cost::default();
    let layers: std::collections::BTreeSet<usize> =
        mapping.ops.iter().map(|o| o.layer).collect();
    for layer in layers {
        for slot in layer_slots(mapping, layer) {
            // ops in a slot run in parallel on disjoint arrays: latency is
            // the max, energies add.
            let mut slot_cost = Cost::default();
            for (k, &oi) in slot.iter().enumerate() {
                let c = op_cost(mapping, params, oi);
                if k == 0 {
                    slot_cost = c;
                } else {
                    slot_cost.parallel_merge(&c);
                }
            }
            total += slot_cost;
        }
        total += layer_dpu_cost(params);
    }
    let _ = cfg;
    total
}

/// Full report for (model, strategy, ADC config).
pub fn cost_report(
    cfg: &ModelConfig,
    params: &CimParams,
    strategy: Strategy,
) -> CostReport {
    let mapping = crate::mapping::map_model(cfg, params, strategy);
    cost_report_for_mapping(cfg, &mapping, params)
}

/// Report for a pre-computed mapping.
pub fn cost_report_for_mapping(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
) -> CostReport {
    let per_token = per_token_cost(cfg, mapping, params);
    let seq = cfg.seq;
    let total = Cost {
        latency: Latency {
            analog_ns: per_token.latency.analog_ns * seq as f64,
            adc_ns: per_token.latency.adc_ns * seq as f64,
            comm_ns: per_token.latency.comm_ns * seq as f64,
            dpu_ns: per_token.latency.dpu_ns * seq as f64,
            mha_ns: per_token.latency.mha_ns * seq as f64,
        },
        energy: Energy {
            analog_nj: per_token.energy.analog_nj * seq as f64,
            adc_nj: per_token.energy.adc_nj * seq as f64,
            comm_nj: per_token.energy.comm_nj * seq as f64,
            dpu_nj: per_token.energy.dpu_nj * seq as f64,
            mha_nj: per_token.energy.mha_nj * seq as f64,
        },
    };
    CostReport {
        model: cfg.name.to_string(),
        strategy: mapping.strategy,
        adcs_per_array: params.adcs_per_array,
        adc_bits: super::adc_bits_for(params, mapping.strategy, mapping.b),
        per_token,
        total,
        seq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::geomean;

    fn speedups(params: &CimParams) -> (f64, f64) {
        // geomean speedup of (SparseMap, DenseMap) over Linear across the
        // three paper models — the Fig. 7a quantities.
        let mut sp = Vec::new();
        let mut de = Vec::new();
        for cfg in ModelConfig::paper_models() {
            let lin = cost_report(&cfg, params, Strategy::Linear);
            let s = cost_report(&cfg, params, Strategy::SparseMap);
            let d = cost_report(&cfg, params, Strategy::DenseMap);
            sp.push(lin.latency_ms() / s.latency_ms());
            de.push(lin.latency_ms() / d.latency_ms());
        }
        (geomean(&sp), geomean(&de))
    }

    #[test]
    fn fig7a_latency_shape() {
        // paper: SparseMap 1.59x, DenseMap 1.73x over Linear (geomean),
        // DenseMap 1.08x over SparseMap. Accept +/-20%.
        let params = CimParams::default();
        let (sp, de) = speedups(&params);
        assert!((1.3..1.95).contains(&sp), "sparse speedup {sp}");
        assert!((1.4..2.1).contains(&de), "dense speedup {de}");
        assert!(de > sp, "DenseMap must beat SparseMap at 1 ADC/array");
        let ratio = de / sp;
        assert!((1.0..1.35).contains(&ratio), "dense/sparse {ratio}");
    }

    #[test]
    fn fig7b_energy_shape() {
        // paper: SparseMap 1.61x, DenseMap 1.74x energy reduction.
        let params = CimParams::default();
        let mut sp = Vec::new();
        let mut de = Vec::new();
        for cfg in ModelConfig::paper_models() {
            let lin = cost_report(&cfg, &params, Strategy::Linear);
            let s = cost_report(&cfg, &params, Strategy::SparseMap);
            let d = cost_report(&cfg, &params, Strategy::DenseMap);
            sp.push(lin.energy_mj() / s.energy_mj());
            de.push(lin.energy_mj() / d.energy_mj());
        }
        let (sp, de) = (geomean(&sp), geomean(&de));
        assert!((1.3..2.0).contains(&sp), "sparse energy gain {sp}");
        assert!((1.4..2.2).contains(&de), "dense energy gain {de}");
        assert!(de > sp);
    }

    #[test]
    fn fig8_dense_flat_beyond_8_adcs() {
        let cfg = ModelConfig::bert_large();
        let at = |adcs: usize| {
            let p = CimParams::default().with_adcs_per_array(adcs);
            cost_report(&cfg, &p, Strategy::DenseMap).latency_ms()
        };
        let l8 = at(8);
        let l16 = at(16);
        let l32 = at(32);
        // usable ADCs capped at lanes=8 -> no further latency gain
        assert!((l16 / l8 - 1.0).abs() < 0.05, "16 vs 8: {l16} vs {l8}");
        assert!((l32 / l8 - 1.0).abs() < 0.05, "32 vs 8: {l32} vs {l8}");
    }

    #[test]
    fn fig8_crossover() {
        // paper: DenseMap best at 4 ADCs/array; SparseMap best at 32.
        let cfg = ModelConfig::bert_large();
        let lat = |s: Strategy, adcs: usize| {
            let p = CimParams::default().with_adcs_per_array(adcs);
            cost_report(&cfg, &p, s).latency_ms()
        };
        // 4 ADCs: dense <= sparse < linear
        assert!(lat(Strategy::DenseMap, 4) < lat(Strategy::Linear, 4));
        // 32 ADCs: sparse beats dense clearly and beats linear
        let sp32 = lat(Strategy::SparseMap, 32);
        let de32 = lat(Strategy::DenseMap, 32);
        let li32 = lat(Strategy::Linear, 32);
        assert!(sp32 < li32, "sparse@32 {sp32} vs linear@32 {li32}");
        assert!(
            de32 / sp32 > 1.5,
            "dense@32 should trail sparse@32 clearly: {}",
            de32 / sp32
        );
    }

    #[test]
    fn per_token_positive_and_decomposed() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let r = cost_report(&cfg, &params, Strategy::SparseMap);
        assert!(r.per_token.latency.adc_ns > 0.0);
        assert!(r.per_token.latency.analog_ns > 0.0);
        assert!(r.per_token.energy.adc_nj > 0.0);
        assert!(
            (r.total.latency.total_ns()
                - r.per_token.latency.total_ns() * cfg.seq as f64)
                .abs()
                < 1.0
        );
    }
}
