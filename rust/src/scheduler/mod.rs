//! Mapping-aware scheduling (paper §III-C): command-stream generation
//! for CIM arrays and the latency/energy model over mapped models.
//!
//! The scheduler knows the memory mapping and block-diagonal sparsity and
//! generates row-activation masks + conversion commands so the packed
//! layouts execute *correctly* (activating all rows of a DenseMap array
//! would mix lanes — `sim::exec` demonstrates both the correct schedules
//! and that failure mode). `timing` walks the same structures to produce
//! Fig. 7/8 latency and energy, and `plan` compiles them once into the
//! allocation-free per-token replay tables the executor runs from
//! ([`compile_plan`], built next to [`placement_schedule`]).

pub mod plan;
pub mod timing;

pub use plan::{compile_plan, CompiledOpPlan, CompiledPass, ModelPlan, TilePasses};

use crate::mapping::{Factor, ModelMapping, Placement, Strategy};

/// One scheduler-issued CIM command (§III-C "memory commands").
#[derive(Clone, Debug, PartialEq)]
pub enum CimCommand {
    /// Program weights into an array region (offline, before inference).
    WriteArray {
        array: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    },
    /// Drive input voltages on a set of rows of an array (analog pass).
    DriveRows { array: usize, rows: Vec<usize> },
    /// Convert a set of columns through the array's (shared) ADCs.
    Convert {
        array: usize,
        cols: Vec<usize>,
        bits: u32,
    },
    /// Shift-add partial outputs into an accumulator (digital).
    ShiftAdd { array: usize },
    /// Route/realign an output vector (block rotation or permutation).
    Route { rotation: usize },
}

/// Row/column geometry of one placement inside its array.
///
/// * SparseMap (`diag == 0`) places block `j` at rows/cols `[j*b, (j+1)*b)`.
/// * DenseMap places block `j` of the lane at rows `[j*b, (j+1)*b)` and
///   cols `[((j+diag) % lanes)*b, ...)` — the diagonal-index layout whose
///   output arrives rotated by `diag` block positions (§III-B2a).
pub fn placement_block_coords(p: &Placement, m: usize) -> Vec<(usize, usize)> {
    let b = p.block_dim;
    let lanes = (m / b).max(1);
    (0..p.blocks)
        .map(|j| match p.factor {
            Factor::Dense => (0, 0),
            _ => (j * b, ((j + p.diag) % lanes) * b),
        })
        .collect()
}

/// One analog pass: the rows to drive and the columns to convert.
/// `rows[k]` carries element `k` of the placement's input segment, and
/// `cols[k]` yields element `k` of its (pre-routing) output segment.
#[derive(Clone, Debug, PartialEq)]
pub struct AnalogPass {
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
}

/// Scheduler-issued execution plan for one placement's per-token work:
/// the ordered analog passes plus the block rotation the router must
/// undo afterwards (§III-B2a lane de-rotation).
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementSchedule {
    pub array: usize,
    pub passes: Vec<AnalogPass>,
    pub rotation: usize,
}

/// Build the activation schedule for one placement.
///
/// * `dense_walk = false` — whole-lane pass: drive every block's rows at
///   once, convert every block's columns, route by `diag`. Correct for
///   SparseMap/Linear (row- and column-disjoint blocks).
/// * `dense_walk = true` — the §III-C DenseMap walk: one pass per block-
///   row group (other co-resident lanes stay quiescent), converting only
///   that block's column group; outputs come out pre-aligned
///   (rotation 0) because the walk follows the diagonal.
pub fn placement_schedule(p: &Placement, m: usize, dense_walk: bool) -> PlacementSchedule {
    let b = p.block_dim.min(m);
    let coords = placement_block_coords(p, m);
    if dense_walk && p.factor != Factor::Dense {
        let passes = coords
            .iter()
            .map(|&(r0, c0)| AnalogPass {
                rows: (r0..r0 + b).collect(),
                cols: (c0..c0 + b).collect(),
            })
            .collect();
        PlacementSchedule {
            array: p.array,
            passes,
            rotation: 0,
        }
    } else {
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        for &(r0, c0) in &coords {
            rows.extend(r0..r0 + b);
            cols.extend(c0..c0 + b);
        }
        PlacementSchedule {
            array: p.array,
            passes: vec![AnalogPass { rows, cols }],
            rotation: p.diag,
        }
    }
}

/// Generate the per-token command stream to execute one placement's
/// analog pass: activate exactly the rows its blocks occupy, convert
/// exactly the columns they drive, then route the rotated output.
pub fn commands_for_placement(
    p: &Placement,
    m: usize,
    bits: u32,
) -> Vec<CimCommand> {
    placement_pass_commands(p, m, bits, false)
}

/// Command form of [`placement_schedule`]: a `DriveRows`/`Convert` pair
/// per analog pass, closed by the `Route` realignment.
pub fn placement_pass_commands(
    p: &Placement,
    m: usize,
    bits: u32,
    dense_walk: bool,
) -> Vec<CimCommand> {
    let sched = placement_schedule(p, m, dense_walk);
    let mut out = Vec::with_capacity(2 * sched.passes.len() + 1);
    for pass in &sched.passes {
        out.push(CimCommand::DriveRows {
            array: sched.array,
            rows: pass.rows.clone(),
        });
        out.push(CimCommand::Convert {
            array: sched.array,
            cols: pass.cols.clone(),
            bits,
        });
    }
    out.push(CimCommand::Route {
        rotation: sched.rotation,
    });
    out
}

/// Per-token command stream over the WHOLE mapped model: layers in
/// order, dependency slots in order (`timing::layer_slots`), the Right
/// factor's placements before the Left's (Monarch stage order), with
/// one `ShiftAdd` per column-partition partial-sum combine. The decode
/// engine's executor consumes the same per-placement schedules
/// ([`placement_schedule`]) this stream is built from; the stream
/// itself is the auditable command-level view (property-tested against
/// the placements in `tests/prop_scheduler.rs`).
pub fn token_commands(
    mapping: &ModelMapping,
    params: &crate::cim::CimParams,
) -> Vec<CimCommand> {
    let bits = adc_bits_for(params, mapping.strategy, mapping.b);
    let dense_walk = mapping.strategy == Strategy::DenseMap;
    let mut out = Vec::new();
    let layers: std::collections::BTreeSet<usize> =
        mapping.ops.iter().map(|o| o.layer).collect();
    for layer in layers {
        for slot in timing::layer_slots(mapping, layer) {
            for &oi in &slot {
                for factor in [Factor::Right, Factor::Left, Factor::Dense] {
                    for p in mapping
                        .placements
                        .iter()
                        .filter(|p| p.op == oi && p.factor == factor)
                    {
                        out.extend(placement_pass_commands(p, mapping.m, bits, dense_walk));
                    }
                }
                let op = &mapping.ops[oi];
                if let Some(&a) = op.arrays.first() {
                    // one accumulate per column-partition combine, matching
                    // the partial_adds the timing model charges for
                    for _ in 0..op.partial_adds {
                        out.push(CimCommand::ShiftAdd { array: a });
                    }
                }
            }
        }
    }
    out
}

/// Program-time command stream: one `WriteArray` per placed block.
pub fn write_commands(mapping: &ModelMapping) -> Vec<CimCommand> {
    let mut out = Vec::new();
    for p in &mapping.placements {
        let b = p.block_dim;
        for (r0, c0) in placement_block_coords(p, mapping.m) {
            out.push(CimCommand::WriteArray {
                array: p.array,
                row0: r0,
                col0: c0,
                rows: b.min(mapping.m),
                cols: b.min(mapping.m),
            });
        }
    }
    out
}

/// ADC resolution policy per strategy (§IV-B: Linear 8 b, SparseMap 5 b,
/// DenseMap 3 b at the default b=32, m=256 geometry). Derived from the
/// active-row rule in `cim::adc`:
/// * Linear drives all m rows -> `required_bits(m)`.
/// * SparseMap drives one block per column -> `required_bits(b)`.
/// * DenseMap schedules row groups of m/b rows -> `required_bits(m/b)`
///   (the paper's 3 b operating point; see DESIGN.md §5).
pub fn adc_bits_for(params: &crate::cim::CimParams, strategy: Strategy, b: usize) -> u32 {
    use crate::cim::adc::required_bits;
    let m = params.array_dim;
    match strategy {
        Strategy::Linear => required_bits(params, m),
        Strategy::SparseMap => required_bits(params, b.max(1)),
        Strategy::DenseMap => required_bits(params, (m / b.max(1)).max(2)),
    }
}

/// ADCs an op can actually exploit in one array: Linear/SparseMap mux at
/// column granularity; DenseMap's rotation-pair routing muxes at block
/// granularity, capping usable ADCs at the lane count (why Fig. 8 shows
/// DenseMap flat beyond m/b = 8 ADCs/array).
pub fn usable_adcs(params: &crate::cim::CimParams, strategy: Strategy, b: usize) -> usize {
    match strategy {
        Strategy::Linear | Strategy::SparseMap => params.adcs_per_array,
        Strategy::DenseMap => params.adcs_per_array.min((params.array_dim / b.max(1)).max(1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimParams;
    use crate::mapping::{map_model, Strategy};
    use crate::model::ModelConfig;

    #[test]
    fn adc_policy_matches_paper() {
        let p = CimParams::default();
        assert_eq!(adc_bits_for(&p, Strategy::Linear, 32), 8);
        assert_eq!(adc_bits_for(&p, Strategy::SparseMap, 32), 5);
        assert_eq!(adc_bits_for(&p, Strategy::DenseMap, 32), 3);
    }

    #[test]
    fn usable_adcs_cap() {
        let p = CimParams::default().with_adcs_per_array(32);
        assert_eq!(usable_adcs(&p, Strategy::Linear, 32), 32);
        assert_eq!(usable_adcs(&p, Strategy::SparseMap, 32), 32);
        assert_eq!(usable_adcs(&p, Strategy::DenseMap, 32), 8);
        let p1 = CimParams::default();
        assert_eq!(usable_adcs(&p1, Strategy::DenseMap, 32), 1);
    }

    #[test]
    fn dense_commands_touch_disjoint_rows_per_lane() {
        let cfg = ModelConfig::bert_large();
        let p = CimParams::default();
        let mm = map_model(&cfg, &p, Strategy::DenseMap);
        // Two placements in the same array must convert different column
        // sets at the same row positions only if diag differs.
        let a0 = mm.placements[0].array;
        let same_array: Vec<_> = mm
            .placements
            .iter()
            .filter(|pl| pl.array == a0)
            .collect();
        assert!(same_array.len() > 1);
        let mut col_sets = Vec::new();
        for pl in &same_array {
            let cmds = commands_for_placement(pl, mm.m, 3);
            if let CimCommand::Convert { cols, .. } = &cmds[1] {
                let mut c = cols.clone();
                c.sort_unstable();
                col_sets.push((pl.diag, c));
            }
        }
        // full lanes cover all columns; what distinguishes them is the
        // row->col pairing, i.e. the diag. Verify diags are unique.
        let mut diags: Vec<usize> = same_array.iter().map(|p| p.diag).collect();
        diags.sort_unstable();
        diags.dedup();
        assert_eq!(diags.len(), same_array.len());
    }

    #[test]
    fn placement_schedule_walk_vs_whole_lane() {
        let cfg = ModelConfig::tiny();
        let p = CimParams::default();
        let mm = map_model(&cfg, &p, Strategy::DenseMap);
        let pl = &mm.placements[0];
        let whole = placement_schedule(pl, mm.m, false);
        assert_eq!(whole.passes.len(), 1);
        assert_eq!(whole.rotation, pl.diag);
        assert_eq!(whole.passes[0].rows.len(), pl.blocks * mm.b);
        let walk = placement_schedule(pl, mm.m, true);
        assert_eq!(walk.passes.len(), pl.blocks);
        assert_eq!(walk.rotation, 0, "walk outputs come out pre-aligned");
        for pass in &walk.passes {
            assert_eq!(pass.rows.len(), mm.b);
            assert_eq!(pass.cols.len(), mm.b);
        }
        // the walk covers exactly the whole-lane row set
        let mut walk_rows: Vec<usize> =
            walk.passes.iter().flat_map(|p| p.rows.clone()).collect();
        let mut whole_rows = whole.passes[0].rows.clone();
        walk_rows.sort_unstable();
        whole_rows.sort_unstable();
        assert_eq!(walk_rows, whole_rows);
    }

    #[test]
    fn token_commands_cover_every_op() {
        let cfg = ModelConfig::tiny();
        let p = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &p, strategy);
            let cmds = token_commands(&mm, &p);
            // every op's every placement contributes at least one drive
            let drives = cmds
                .iter()
                .filter(|c| matches!(c, CimCommand::DriveRows { .. }))
                .count();
            let min_expected = mm.placements.len();
            assert!(
                drives >= min_expected,
                "{strategy:?}: {drives} drives < {min_expected} placements"
            );
            // stream replays identically (pure function of the mapping)
            assert_eq!(cmds, token_commands(&mm, &p));
        }
    }

    #[test]
    fn write_commands_cover_all_blocks() {
        let cfg = ModelConfig::tiny();
        let p = CimParams::default();
        let mm = map_model(&cfg, &p, Strategy::SparseMap);
        let writes = write_commands(&mm);
        let total_blocks: usize = mm.placements.iter().map(|p| p.blocks).sum();
        assert_eq!(writes.len(), total_blocks);
    }
}
