//! Compiled execution plans (§III-C, executed): the per-token work of a
//! whole mapped model resolved ONCE, at chip-programming time, into a
//! flat pass table the simulator replays allocation-free.
//!
//! [`super::placement_schedule`] derives each placement's row-activation
//! masks, conversion columns and output rotation from the mapping — but
//! re-deriving it per token allocates index vectors on every analog pass
//! and leaves the rotation as a separate realignment step. `compile_plan`
//! walks the same schedules exactly once and folds everything into
//! [`CompiledPass`] records:
//!
//! * `rows` — the rows to drive, verbatim the scheduler's `DriveRows` set
//!   (`rows[k]` for `k < n_in` carries input element `src + k`; any
//!   remaining rows are driven at zero — Linear's padding rows).
//! * `cols` — the columns to convert, **pre-rotated**: `cols[k]` is the
//!   column whose bitline feeds output element `dst + k`, so the
//!   §III-B2a lane de-rotation costs nothing at token time and only the
//!   columns the schedule actually converts are computed
//!   ([`crate::cim::crossbar::Crossbar::mvm_pass_cols`]).
//! * `src`/`dst` — offsets into the stage input/output vectors, so the
//!   executor's token loop is pure index-driven replay.
//! * `row_bits`/`col_bits` — the same sets re-encoded as u64 bit-block
//!   words with per-word dense-offset prefix sums
//!   ([`crate::cim::BitBlocks`], ISSUE 6): the default replay iterates
//!   set-bit *runs* of these (contiguous sparse↔dense spans) instead of
//!   the index lists, staging inputs with block copies and accumulating
//!   columns with contiguous slice zips
//!   ([`crate::cim::crossbar::Crossbar::mvm_pass_bits`]). The index
//!   lists are kept as the auditable baseline encoding
//!   (`sim::exec::ReplayMode::IndexList`) and for schedule
//!   cross-checks.
//!
//! The replay is bit-identical to a freshly recomputed
//! `placement_schedule` execution (property-tested in
//! `tests/prop_exec_plan.rs`) — the plan changes *when* scheduling work
//! happens, never *what* the chip computes.

use std::ops::Range;

use super::placement_schedule;
use crate::cim::bitblocks::BitBlocks;
use crate::mapping::{Factor, MappedOp, ModelMapping, Strategy};

/// One fully resolved analog pass of the per-token command stream.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledPass {
    /// Physical array driven by this pass.
    pub array: usize,
    /// Rows to drive — exactly the scheduler's `DriveRows` set.
    pub rows: Vec<usize>,
    /// `rows[..n_in]` carry input elements `src..src + n_in`; rows past
    /// `n_in` are driven at zero (Linear's zero-padded tail).
    pub n_in: usize,
    /// Offset of this pass's input segment in the stage input vector.
    pub src: usize,
    /// Columns to convert; `cols[k]`'s bitline feeds output `dst + k`
    /// (lane rotation already folded in).
    pub cols: Vec<usize>,
    /// Offset of this pass's output segment in the stage output vector.
    pub dst: usize,
    /// Per-bitline accumulation depth: the most programmed cells any
    /// converted column sums over this pass. Monarch passes convert
    /// block-diagonal columns (`b` cells each, regardless of how many
    /// blocks the pass drives); Linear tiles accumulate one cell per
    /// nonzero-driven row (`n_in`). This — not the driven-row count —
    /// is what sizes the exact-conversion ADC resolution
    /// (`cim::adc::required_bits`), mirroring the §IV-B per-strategy
    /// resolution policy (`scheduler::adc_bits_for`).
    pub conv_depth: usize,
    /// Bit-block encoding of `rows` over universe `0..m` (one u64 word
    /// per 64 array rows + per-word dense-offset prefix sums) — what
    /// the default replay iterates.
    pub row_bits: BitBlocks,
    /// Bit-block encoding of `cols` (same layout).
    pub col_bits: BitBlocks,
}

impl CompiledPass {
    /// Resolve one pass from the scheduler's index lists, deriving the
    /// bit-block encodings over the array's `0..m` universe. Every
    /// schedule the planner walks produces strictly ascending row and
    /// column lists (SparseMap places on the main diagonal, the
    /// DenseMap walk is block-granular, Linear converts an identity
    /// prefix), so the encoding is exact — `from_sorted` asserts it.
    #[allow(clippy::too_many_arguments)]
    fn new(
        array: usize,
        rows: Vec<usize>,
        n_in: usize,
        src: usize,
        cols: Vec<usize>,
        dst: usize,
        conv_depth: usize,
        m: usize,
    ) -> CompiledPass {
        let row_bits = BitBlocks::from_sorted(&rows, m);
        let col_bits = BitBlocks::from_sorted(&cols, m);
        CompiledPass {
            array,
            rows,
            n_in,
            src,
            cols,
            dst,
            conv_depth,
            row_bits,
            col_bits,
        }
    }
}

/// Pass ranges of one d x d tile: the Right-factor passes run first,
/// then (after the stride permutation) the Left-factor passes.
#[derive(Clone, Debug, PartialEq)]
pub struct TilePasses {
    pub right: Range<usize>,
    pub left: Range<usize>,
}

/// Compiled per-token plan of one mapped op.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledOpPlan {
    /// Monarch strategies: pass ranges per d x d tile (indexed by the
    /// row-major tile id `i * col_tiles + j`). Empty for Linear.
    pub tiles: Vec<TilePasses>,
    /// Flat resolved pass table (tile-major for Monarch; placement
    /// allocation order for Linear, fixing partial-sum order).
    pub passes: Vec<CompiledPass>,
    /// Linear partial sums accumulate (`+=`) into the output; Monarch
    /// stage passes assign (their output segments are disjoint & total).
    pub accumulate: bool,
}

/// Compiled per-token plan of a whole mapped model — one entry per op,
/// aligned with `mapping.ops`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPlan {
    pub ops: Vec<CompiledOpPlan>,
    /// Array dimension the passes index into.
    pub m: usize,
}

impl ModelPlan {
    /// Widest conversion any pass performs (scratch sizing).
    pub fn max_cols(&self) -> usize {
        self.ops
            .iter()
            .flat_map(|o| o.passes.iter())
            .map(|p| p.cols.len())
            .max()
            .unwrap_or(0)
    }

    /// Total analog passes one full-model replay walks (every op, every
    /// tile, both factors). This is the per-position command overhead
    /// that batched decode and chunked prefill amortize: a replay with
    /// `lanes` lanes walks these tables once instead of `lanes` times —
    /// reported by `benches/decode_throughput.rs` alongside the measured
    /// tokens/sec so the amortization claim is inspectable.
    pub fn total_passes(&self) -> usize {
        self.ops.iter().map(|o| o.passes.len()).sum()
    }

    /// Histogram of ADC conversions by per-bitline accumulation depth:
    /// `hist[depth]` counts the converted columns whose bitline sums
    /// `depth` programmed cells over one full-model replay. The analog
    /// DSE (`coordinator::dse`) reads this to report what fraction of a
    /// replay's conversions a resolution cap actually re-quantizes
    /// (`cim::adc::required_bits(depth) > cap`).
    pub fn conversion_depth_histogram(&self) -> Vec<usize> {
        let mut hist = vec![0usize; self.m + 1];
        for op in &self.ops {
            for pass in &op.passes {
                hist[pass.conv_depth.min(self.m)] += pass.cols.len();
            }
        }
        hist
    }
}

/// Geometry of one Linear placement's m x m tile: `(rp, cp, rows_here,
/// cols_here)`. Single source of the `tile == rp * col_parts + cp`
/// convention `mapping::linear` allocates with — shared by programming,
/// plan compilation and the recompute path so they can't drift apart.
pub fn linear_tile_geometry(
    op: &MappedOp,
    tile: usize,
    m: usize,
) -> (usize, usize, usize, usize) {
    let col_parts = op.cols.div_ceil(m);
    let (rp, cp) = (tile / col_parts, tile % col_parts);
    (rp, cp, m.min(op.rows - rp * m), m.min(op.cols - cp * m))
}

/// Resolve the whole mapping's per-token schedules into a [`ModelPlan`].
///
/// Pure function of the mapping (deterministic), called once at
/// `FunctionalChip::program_rect` time; the token loop only reads it.
pub fn compile_plan(mapping: &ModelMapping) -> ModelPlan {
    let m = mapping.m;
    // placement indices grouped per op, insertion order preserved
    let mut per_op: Vec<Vec<usize>> = vec![Vec::new(); mapping.ops.len()];
    for (i, p) in mapping.placements.iter().enumerate() {
        per_op[p.op].push(i);
    }
    let ops = mapping
        .ops
        .iter()
        .enumerate()
        .map(|(oi, op)| match mapping.strategy {
            Strategy::Linear => compile_linear_op(mapping, op, &per_op[oi]),
            _ => compile_monarch_op(mapping, op, &per_op[oi]),
        })
        .collect();
    ModelPlan { ops, m }
}

fn compile_linear_op(
    mapping: &ModelMapping,
    op: &MappedOp,
    op_placements: &[usize],
) -> CompiledOpPlan {
    let m = mapping.m;
    let mut passes = Vec::with_capacity(op_placements.len());
    for &pi in op_placements {
        let p = &mapping.placements[pi];
        let (rp, cp, rows_here, cols_here) = linear_tile_geometry(op, p.tile, m);
        let sched = placement_schedule(p, m, false);
        let pass = sched.passes.into_iter().next().expect("schedule has a pass");
        passes.push(CompiledPass::new(
            p.array,
            pass.rows,
            cols_here,
            cp * m,
            // The executor consumes only the columns that land in the
            // output tile; the command stream still converts all m.
            pass.cols[..rows_here].to_vec(),
            rp * m,
            // dense tile: every nonzero-driven row feeds every bitline
            cols_here,
            m,
        ));
    }
    CompiledOpPlan {
        tiles: Vec::new(),
        passes,
        accumulate: true,
    }
}

fn compile_monarch_op(
    mapping: &ModelMapping,
    op: &MappedOp,
    op_placements: &[usize],
) -> CompiledOpPlan {
    let m = mapping.m;
    let b = mapping.b.max(1);
    let lanes = (m / b).max(1);
    let dense_walk = mapping.strategy == Strategy::DenseMap;
    let mut passes = Vec::new();
    let mut tiles = Vec::with_capacity(op.tiles);
    for tile in 0..op.tiles {
        let right_start = passes.len();
        push_factor_passes(
            mapping,
            op_placements,
            tile,
            Factor::Right,
            dense_walk,
            lanes,
            b,
            &mut passes,
        );
        let left_start = passes.len();
        push_factor_passes(
            mapping,
            op_placements,
            tile,
            Factor::Left,
            dense_walk,
            lanes,
            b,
            &mut passes,
        );
        tiles.push(TilePasses {
            right: right_start..left_start,
            left: left_start..passes.len(),
        });
    }
    CompiledOpPlan {
        tiles,
        passes,
        accumulate: false,
    }
}

#[allow(clippy::too_many_arguments)]
fn push_factor_passes(
    mapping: &ModelMapping,
    op_placements: &[usize],
    tile: usize,
    factor: Factor,
    dense_walk: bool,
    lanes: usize,
    b: usize,
    passes: &mut Vec<CompiledPass>,
) {
    let m = mapping.m;
    for &pi in op_placements {
        let p = &mapping.placements[pi];
        if p.factor != factor || p.tile != tile {
            continue;
        }
        // Input segment of this lane starts at block `lane_of_factor *
        // lanes` of the stage vector (same convention as the executor).
        let base = p.lane_of_factor * lanes;
        let sched = placement_schedule(p, m, dense_walk);
        if dense_walk {
            // §III-C walk: one pass per block-row group; outputs arrive
            // pre-aligned (the walk follows the diagonal), so src == dst.
            for (j, pass) in sched.passes.into_iter().enumerate() {
                let off = (base + j) * b;
                let n_in = pass.rows.len();
                passes.push(CompiledPass::new(
                    p.array, pass.rows, n_in, off, pass.cols, off, b, m,
                ));
            }
        } else {
            // Whole-lane pass: the schedule's column list already walks
            // the diagonal layout (block j reads column block
            // (j + diag) % lanes), which IS the §III-B2a de-rotation —
            // `cols[k]` feeds output `dst + k` directly.
            let pass = sched.passes.into_iter().next().expect("schedule has a pass");
            let off = base * b;
            let n_in = pass.rows.len();
            // Block-diagonal: however many blocks the whole-lane pass
            // drives, each converted column sums only its own block's
            // b cells.
            passes.push(CompiledPass::new(
                p.array, pass.rows, n_in, off, pass.cols, off, b, m,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimParams;
    use crate::mapping::map_model;
    use crate::model::ModelConfig;

    #[test]
    fn plan_is_deterministic_and_covers_all_ops() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            assert_eq!(plan.ops.len(), mm.ops.len());
            assert_eq!(plan, compile_plan(&mm), "{strategy:?} not deterministic");
            let total_passes: usize = plan.ops.iter().map(|o| o.passes.len()).sum();
            assert!(total_passes >= mm.placements.len(), "{strategy:?}");
            assert!(plan.max_cols() <= mm.m, "{strategy:?}");
        }
    }

    #[test]
    fn total_passes_counts_every_compiled_pass() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            let by_hand: usize = plan.ops.iter().map(|o| o.passes.len()).sum();
            assert_eq!(plan.total_passes(), by_hand);
            assert!(plan.total_passes() >= mm.placements.len(), "{strategy:?}");
        }
    }

    #[test]
    fn monarch_tiles_partition_the_pass_table() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            for (oi, op) in plan.ops.iter().enumerate() {
                assert_eq!(op.tiles.len(), mm.ops[oi].tiles);
                assert!(!op.accumulate);
                let mut next = 0usize;
                for t in &op.tiles {
                    assert_eq!(t.right.start, next);
                    assert_eq!(t.right.end, t.left.start);
                    assert!(t.right.end > t.right.start, "empty Right stage");
                    assert!(t.left.end > t.left.start, "empty Left stage");
                    next = t.left.end;
                }
                assert_eq!(next, op.passes.len());
            }
        }
    }

    #[test]
    fn densemap_passes_are_block_granular() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::DenseMap);
        let plan = compile_plan(&mm);
        for op in &plan.ops {
            for pass in &op.passes {
                assert_eq!(pass.rows.len(), mm.b, "walk drives one block");
                assert_eq!(pass.cols.len(), mm.b, "walk converts one block");
                assert_eq!(pass.n_in, mm.b);
                assert_eq!(pass.src, pass.dst, "walk outputs pre-aligned");
            }
        }
    }

    #[test]
    fn pass_bit_blocks_mirror_index_lists() {
        // the two encodings of every compiled pass must describe the
        // same sets, with rank() recovering each index's dense position
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            for op in &plan.ops {
                for pass in &op.passes {
                    assert_eq!(pass.row_bits.indices(), pass.rows, "{strategy:?}");
                    assert_eq!(pass.col_bits.indices(), pass.cols, "{strategy:?}");
                    assert_eq!(pass.row_bits.bits(), mm.m, "{strategy:?}");
                    assert_eq!(pass.col_bits.bits(), mm.m, "{strategy:?}");
                    for (k, &r) in pass.rows.iter().enumerate() {
                        assert_eq!(pass.row_bits.rank(r), k, "{strategy:?} row");
                    }
                    for (k, &c) in pass.cols.iter().enumerate() {
                        assert_eq!(pass.col_bits.rank(c), k, "{strategy:?} col");
                    }
                }
            }
        }
    }

    #[test]
    fn conv_depth_is_block_dim_for_monarch_and_n_in_for_linear() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            for op in &plan.ops {
                for pass in &op.passes {
                    let want = match strategy {
                        Strategy::Linear => pass.n_in,
                        _ => mm.b,
                    };
                    assert_eq!(pass.conv_depth, want, "{strategy:?}");
                    assert!(pass.conv_depth <= mm.m, "{strategy:?}");
                }
            }
        }
    }

    #[test]
    fn conversion_depth_histogram_counts_every_converted_column() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let plan = compile_plan(&mm);
            let hist = plan.conversion_depth_histogram();
            assert_eq!(hist.len(), mm.m + 1);
            let total: usize = hist.iter().sum();
            let by_hand: usize = plan
                .ops
                .iter()
                .flat_map(|o| o.passes.iter())
                .map(|p| p.cols.len())
                .sum();
            assert_eq!(total, by_hand, "{strategy:?}");
            if strategy != Strategy::Linear {
                // Monarch strategies convert only b-deep bitlines
                let at_b: usize = hist[mm.b];
                assert_eq!(at_b, total, "{strategy:?} all depth-b");
            }
        }
    }

    #[test]
    fn linear_passes_truncate_to_tile_geometry() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::Linear);
        let plan = compile_plan(&mm);
        for (oi, op) in plan.ops.iter().enumerate() {
            assert!(op.accumulate);
            assert_eq!(op.passes.len(), mm.ops[oi].tiles);
            for (tile, pass) in op.passes.iter().enumerate() {
                let (rp, cp, rows_here, cols_here) =
                    linear_tile_geometry(&mm.ops[oi], tile, mm.m);
                assert_eq!(pass.rows.len(), mm.m, "all rows driven");
                assert_eq!(pass.n_in, cols_here);
                assert_eq!(pass.src, cp * mm.m);
                assert_eq!(pass.dst, rp * mm.m);
                let want: Vec<usize> = (0..rows_here).collect();
                assert_eq!(pass.cols, want, "identity columns, truncated");
            }
        }
    }
}
