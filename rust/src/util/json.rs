//! Minimal JSON value model, parser and writer.
//!
//! The offline image has no `serde`; this module covers what the repo
//! needs: reading the artifact manifest written by `python/compile/aot.py`,
//! reading golden files, and emitting machine-readable reports.
//!
//! The parser is a straightforward recursive-descent implementation over
//! the JSON grammar (RFC 8259), sufficient for well-formed documents; it
//! reports byte offsets on errors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (the manifest only contains
/// small integers and floats).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Reassemble the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl Json {
    /// Pretty writer: 2-space indentation, one element/key per line.
    /// This is the on-disk artifact format (`util::bench` writes BENCH
    /// JSON through it); `Display` stays compact for logs and wire use.
    /// Both forms reparse to the same value.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, depth + 1);
                    x.pretty_into(out, depth + 1);
                }
                out.push('\n');
                push_indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, depth + 1);
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                push_indent(out, depth);
                out.push('}');
            }
            // scalars and empty containers: the compact writer is right
            scalar => out.push_str(&scalar.to_string()),
        }
    }
}

fn push_indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        // reparse of the writer output matches
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"x": {"y": {"z": 42}}}"#).unwrap();
        assert_eq!(v.path("x.y.z").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.5).to_string(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn pretty_writer_reparses_to_same_value() {
        let v = Json::parse(
            r#"{"bench": "x", "sweep": {"a": [1, 2.5], "b": {"c": null}}, "empty": [], "t": true}"#,
        )
        .unwrap();
        let pretty = v.to_pretty();
        // multi-line, indented, and value-preserving
        assert!(pretty.contains('\n'));
        assert!(pretty.contains("  \"bench\": \"x\""));
        assert!(pretty.contains("\"empty\": []"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        // scalars stay single-line
        assert_eq!(num(3.0).to_pretty(), "3");
        assert_eq!(s("hi").to_pretty(), "\"hi\"");
    }
}
