//! ASCII table renderer for figure/table reproduction output.

/// Column-aligned ASCII table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    /// CSV form (for plotting outside the repo).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// `format!`-friendly ratio, e.g. `1.73x`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// SI-ish formatting for large counts.
pub fn si(x: f64) -> String {
    if x.abs() >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if x.abs() >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x.abs() >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x.abs() >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

/// Time in engineering units from nanoseconds.
pub fn eng_time_ns(ns: f64) -> String {
    crate::util::bench::fmt_ns(ns)
}

/// Energy in engineering units from nanojoules.
pub fn eng_energy_nj(nj: f64) -> String {
    if nj < 1e3 {
        format!("{nj:.2} nJ")
    } else if nj < 1e6 {
        format!("{:.2} µJ", nj / 1e3)
    } else if nj < 1e9 {
        format!("{:.2} mJ", nj / 1e6)
    } else {
        format!("{:.3} J", nj / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["model", "arrays", "util %"]);
        t.row(["bert-large", "1152", "100.0"]);
        t.row(["gpt2-medium", "96", "78.8"]);
        let r = t.render();
        assert!(r.contains("bert-large"));
        assert!(r.lines().all(|l| l.starts_with('+') || l.starts_with('|')));
        // all lines same width
        let ws: Vec<usize> = r.lines().map(|l| l.chars().count()).collect();
        assert!(ws.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(["k", "v"]);
        t.row(["a,b", "c\"d"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"c\"\"d\""));
    }

    #[test]
    fn units() {
        assert_eq!(si(2_500_000.0), "2.50M");
        assert!(eng_energy_nj(1.5e6).contains("mJ"));
        assert_eq!(ratio(1.734), "1.73x");
    }
}
