//! Bench harness for `cargo bench` targets with `harness = false`
//! (offline image lacks `criterion`).
//!
//! Provides warmup, calibrated iteration counts, robust statistics and a
//! criterion-like one-line report, plus helpers for printing the paper's
//! tables/figures from bench binaries.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Bench runner with a fixed wall-clock budget per case.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Respect a quick mode for CI smoke runs.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Self {
            warmup: if quick {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(200)
            },
            budget: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(1)
            },
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the result from being optimized away.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        // Sample in batches so timer overhead is amortized for fast cases.
        let batch = ((1_000_00.0 / per_iter).ceil() as u64).clamp(1, 10_000);
        let mut samples = Vec::new();
        let run_start = Instant::now();
        let mut total_iters = 0u64;
        while run_start.elapsed() < self.budget && samples.len() < 2000 {
            let s = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_ns: samples[samples.len() / 2],
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
        };
        println!(
            "{:<52} {:>12}  p50 {:>12}  ({} iters)",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Output path resolution for a JSON artifact: `--<flag> <path>` (or
/// `--<flag>=<path>`) on the bench/bin command line > `<env>` env var
/// > `<default>`. Shared by every BENCH_*.json emitter so the
/// resolution order can't drift between artifacts.
pub fn artifact_path(flag: &str, env: &str, default: &str) -> PathBuf {
    let long = format!("--{flag}");
    let long_eq = format!("--{flag}=");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == long {
            if let Some(p) = args.next() {
                return p.into();
            }
        } else if let Some(p) = a.strip_prefix(&long_eq) {
            return p.into();
        }
    }
    if let Some(p) = std::env::var_os(env) {
        return p.into();
    }
    default.into()
}

/// Write one JSON artifact (pretty-printed, trailing newline) and log
/// the destination. The single write site behind every BENCH_*.json.
pub fn write_artifact(path: &Path, doc: &Json) -> std::io::Result<()> {
    let mut body = doc.to_pretty();
    body.push('\n');
    std::fs::write(path, body)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Resolve the artifact path ([`artifact_path`]) and write `doc`
/// through the single write site ([`write_artifact`]). A failed write
/// is reported to stderr but does not abort — an unwritable artifact
/// must not take the bench results down with it.
pub fn write_json_artifact(flag: &str, env: &str, default: &str, doc: &Json) {
    let path = artifact_path(flag, env, default);
    if let Err(e) = write_artifact(&path, doc) {
        eprintln!("failed to write {}: {e}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let m = b.bench("noop-ish", || std::hint::black_box(1 + 1)).clone();
        assert!(m.mean_ns > 0.0);
        assert!(m.iters > 0);
        assert!(m.min_ns <= m.p50_ns && m.p50_ns <= m.max_ns);
    }

    #[test]
    fn artifact_path_prefers_env_over_default() {
        // (bench tests can't fake argv; the flag branch is exercised by
        // the CI smoke bench, which passes --bench-json explicitly)
        std::env::set_var("BENCH_TEST_ARTIFACT", "from_env.json");
        let p = artifact_path("no-such-flag", "BENCH_TEST_ARTIFACT", "default.json");
        assert_eq!(p, PathBuf::from("from_env.json"));
        std::env::remove_var("BENCH_TEST_ARTIFACT");
        let p = artifact_path("no-such-flag", "BENCH_TEST_ARTIFACT", "default.json");
        assert_eq!(p, PathBuf::from("default.json"));
    }

    #[test]
    fn write_artifact_is_pretty_and_reparses() {
        use crate::util::json::{num, obj, s};
        let doc = obj(vec![("bench", s("t")), ("v", num(1.0))]);
        let dir = std::env::temp_dir().join("monarch_cim_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact.json");
        write_artifact(&path, &doc).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.ends_with('\n'));
        assert!(body.contains("  \"bench\": \"t\""));
        assert_eq!(Json::parse(body.trim_end()).unwrap(), doc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
