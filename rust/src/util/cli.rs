//! Hand-rolled CLI argument parser (offline image lacks `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a usage printer.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an explicit argument list (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // `--key value` when the next token is not a flag;
                    // bare `--key` otherwise.
                    let takes_value =
                        matches!(it.peek(), Some(nx) if !nx.starts_with("--"));
                    if takes_value {
                        let v = it.next().unwrap();
                        out.flags.entry(body.to_string()).or_default().push(v);
                    } else {
                        out.flags.entry(body.to_string()).or_default().push(String::new());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
            .filter(|s| !s.is_empty())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| {
                s.parse().unwrap_or_else(|_| {
                    eprintln!("error: --{key} expects an integer, got '{s}'");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| {
                s.parse().unwrap_or_else(|_| {
                    eprintln!("error: --{key} expects a number, got '{s}'");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--adcs 4,8,16,32`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{key} expects ints, got '{p}'");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list, e.g. `--sigmas 0,0.01,0.05`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{key} expects numbers, got '{p}'");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = args("figure fig7 --model bert --adcs=4,8 --verbose");
        assert_eq!(a.positional, vec!["figure", "fig7"]);
        assert_eq!(a.get("model"), Some("bert"));
        assert_eq!(a.usize_list_or("adcs", &[]), vec![4, 8]);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None); // bare flag has no value
    }

    #[test]
    fn key_value_space_form() {
        let a = args("--m 256 --b 32 run");
        assert_eq!(a.usize_or("m", 0), 256);
        assert_eq!(a.usize_or("b", 0), 32);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.usize_or("m", 256), 256);
        assert_eq!(a.f64_or("x", 1.5), 1.5);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn f64_lists_parse_like_usize_lists() {
        let a = args("--sigmas 0,0.01,0.05");
        assert_eq!(a.f64_list_or("sigmas", &[]), vec![0.0, 0.01, 0.05]);
        assert_eq!(a.f64_list_or("missing", &[1.5]), vec![1.5]);
    }

    #[test]
    fn repeated_flags_last_wins() {
        let a = args("--m 1 --m 2");
        assert_eq!(a.usize_or("m", 0), 2);
        assert_eq!(a.get_all("m"), vec!["1", "2"]);
    }
}
