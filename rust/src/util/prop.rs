//! Mini property-based testing harness (the offline image has no
//! `proptest`). Runs a property over many PRNG-derived cases and, on
//! failure, retries with the failing seed while halving integer sizes
//! drawn through [`Gen::size`] — a lightweight shrink.
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use monarch_cim::util::prop::{forall, Gen};
//! forall("addition commutes", 100, |g: &mut Gen| {
//!     let (a, b) = (g.usize(0, 1000), g.usize(0, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg32;

/// Case generator handed to properties; wraps a PRNG plus a size budget
/// that the shrinking pass lowers.
pub struct Gen {
    rng: Pcg32,
    /// Scale factor in (0, 1]; shrink passes lower it so size-driven
    /// draws get smaller.
    scale: f64,
    /// Log of draws for failure reports.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            scale,
            log: Vec::new(),
        }
    }

    /// Integer in `[lo, hi]`, biased smaller when shrinking.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.scale).round() as usize;
        let v = self.rng.range(lo, hi_scaled.max(lo) + 1);
        self.log.push(format!("usize[{lo},{hi}] = {v}"));
        v
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.f32() * (hi - lo);
        self.log.push(format!("f32[{lo},{hi}) = {v}"));
        v
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        self.rng.normal_vec(len)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one of the provided values.
    pub fn choose<T: Copy + std::fmt::Debug>(&mut self, xs: &[T]) -> T {
        let v = *self.rng.choose(xs);
        self.log.push(format!("choose{xs:?} = {v:?}"));
        v
    }

    /// Raw PRNG access for bulk data.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases. Panics (test failure) with the
/// seed and draw log of the smallest failing case found.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    let base_seed = 0xC1A0_0000u64 ^ fxhash(name);
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        if let Err(panic) = run_case(&prop, seed, 1.0) {
            // Shrink: retry same seed with smaller size scales; report the
            // smallest still-failing configuration.
            let mut best_scale = 1.0;
            for &scale in &[0.5, 0.25, 0.1, 0.05] {
                if run_case(&prop, seed, scale).is_err() {
                    best_scale = scale;
                }
            }
            let mut g = Gen::new(seed, best_scale);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut g)
            }));
            panic!(
                "property '{name}' failed (case {i}, seed {seed:#x}, scale {best_scale}):\n  draws: {}\n  panic: {}",
                g.log.join(", "),
                panic_msg(&panic),
            );
        }
    }
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    scale: f64,
) -> Result<(), Box<dyn std::any::Any + Send>> {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // silence expected panics
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut g = Gen::new(seed, scale);
        prop(&mut g);
    }));
    std::panic::set_hook(prev);
    r
}

fn panic_msg(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add commutes", 50, |g| {
            let a = g.usize(0, 100);
            let b = g.usize(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails on big", 50, |g| {
                let a = g.usize(0, 100);
                assert!(a < 5, "a too big: {a}");
            });
        });
        let msg = panic_msg(&r.unwrap_err());
        assert!(msg.contains("seed"), "message should name the seed: {msg}");
    }

    #[test]
    fn gen_respects_bounds() {
        forall("bounds", 100, |g| {
            let v = g.usize(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }
}
