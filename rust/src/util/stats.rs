//! Small statistics helpers used by the simulator, benches and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean — the paper reports geomean speedups across models.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation between closest ranks; `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple latency/throughput histogram with fixed log-spaced buckets (ns).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn max(&self) -> f64 {
        max(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        // paper-style: geomean of speedups
        let g = geomean(&[1.59, 1.61, 1.57]);
        assert!((g - 1.59).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.p50() - 50.5).abs() < 1.0);
        assert!(h.p99() >= 99.0);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
