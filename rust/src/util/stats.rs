//! Small statistics helpers used by the simulator, benches and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean — the paper reports geomean speedups across models.
/// Defined over the *positive* samples only: a zero/negative cell (a
/// degenerate sweep point, reachable from bench/report summaries) is
/// skipped rather than panicking the whole summary, and an input with
/// no positive sample reports 0.0 — the crate-wide "no samples"
/// convention. NaN fails the `> 0` test, so it is skipped too.
pub fn geomean(xs: &[f64]) -> f64 {
    let (sum, n) = xs
        .iter()
        .filter(|&&x| x > 0.0)
        .fold((0.0f64, 0usize), |(s, n), &x| (s + x.ln(), n + 1));
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).exp()
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation between closest ranks; `p` in
/// [0,100]. NaN samples are dropped before ranking (one NaN used to
/// panic the `partial_cmp(..).unwrap()` sort — and with it every
/// metrics snapshot at serve time); an empty or all-NaN input reports
/// 0.0, the same "no samples" convention the snapshot guards use.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Smallest non-NaN sample; 0.0 for empty (or all-NaN) input — callers
/// format these into reports, where a bare `inf` placeholder reads as
/// a real measurement.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .reduce(f64::min)
        .unwrap_or(0.0)
}

/// Largest non-NaN sample; 0.0 for empty (or all-NaN) input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .reduce(f64::max)
        .unwrap_or(0.0)
}

/// Buckets per decade of the log-spaced [`Histogram`]. The bucket
/// width ratio is `10^(1/25) ≈ 1.097`, so any percentile estimate is
/// within ~10% (one bucket width) of the exact sorted-vector answer.
const BUCKETS_PER_DECADE: usize = 25;
/// Lower edge of the first log bucket; values `<= HIST_MIN` (including
/// zero — `Instant::elapsed().as_micros()` rounds down to 0 on fast
/// paths) land in a dedicated underflow bucket spanning `[0, HIST_MIN)`.
const HIST_MIN: f64 = 1e-3;
/// Upper edge of the last log bucket; values `>= HIST_MAX` land in a
/// dedicated overflow bucket. The span 1e-3..1e9 covers sub-ns to ~17
/// minutes when samples are microseconds.
const HIST_MAX: f64 = 1e9;
const HIST_DECADES: usize = 12; // log10(HIST_MAX) - log10(HIST_MIN)
/// Total bucket count: underflow + log buckets + overflow. Fixed at
/// compile time — the histogram can NEVER grow with the sample stream.
const HIST_BUCKETS: usize = HIST_DECADES * BUCKETS_PER_DECADE + 2;
const _: () = assert!(HIST_BUCKETS <= 512, "histogram hard cap exceeded");

/// Latency/throughput histogram over fixed log-spaced buckets.
///
/// Storage is a compile-time-sized count array plus exact running
/// `count`/`sum`/`min`/`max` — recording a sample is O(1) and the
/// struct never allocates, so a week-long `serve-load` run holds the
/// same memory as a 10-sample unit test (`histogram_memory_is_constant`
/// pins this). `mean` and `max` are exact; `p50`/`p99` interpolate
/// within the hit bucket and clamp into `[min, max]`, so they are
/// within one bucket width (~10%) of the exact sorted-vector answer
/// and *exactly* right for single-sample or single-valued streams.
/// Non-finite samples are dropped (the crate-wide NaN convention, see
/// [`percentile`]).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u32; HIST_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bucket index for a finite sample.
fn bucket_index(v: f64) -> usize {
    if v < HIST_MIN {
        return 0;
    }
    if v >= HIST_MAX {
        return HIST_BUCKETS - 1;
    }
    let k = ((v.log10() + 3.0) * BUCKETS_PER_DECADE as f64).floor() as isize;
    (k + 1).clamp(1, (HIST_BUCKETS - 2) as isize) as usize
}

/// `[lo, hi)` value range of bucket `i` (the overflow bucket is
/// degenerate: both edges are `HIST_MAX`).
fn bucket_bounds(i: usize) -> (f64, f64) {
    if i == 0 {
        (0.0, HIST_MIN)
    } else if i == HIST_BUCKETS - 1 {
        (HIST_MAX, HIST_MAX)
    } else {
        let lo = -3.0 + (i - 1) as f64 / BUCKETS_PER_DECADE as f64;
        let hi = -3.0 + i as f64 / BUCKETS_PER_DECADE as f64;
        (10f64.powf(lo), 10f64.powf(hi))
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = bucket_index(v);
        debug_assert!(idx < HIST_BUCKETS);
        self.counts[idx] = self.counts[idx].saturating_add(1);
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Finite samples recorded (bucket counts saturate at `u32::MAX`
    /// per bucket; this total keeps counting).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact arithmetic mean (running sum / count); 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile estimate: linear interpolation inside the bucket the
    /// rank falls into, clamped to the exact observed `[min, max]`.
    /// 0.0 when empty (the crate-wide "no samples" convention).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (((p / 100.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c as u64 >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - cum) as f64 / c as f64;
                return (lo + frac * (hi - lo)).clamp(self.min, self.max);
            }
            cum += c as u64;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Exact smallest sample; 0.0 when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest sample; 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Bytes held by this histogram — a compile-time constant (no heap
    /// storage), asserted by the 10^6-sample memory test.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        // paper-style: geomean of speedups
        let g = geomean(&[1.59, 1.61, 1.57]);
        assert!((g - 1.59).abs() < 0.01);
    }

    #[test]
    fn geomean_skips_nonpositive_instead_of_panicking() {
        // regression (ISSUE-8 satellite): a degenerate sweep cell used
        // to assert-panic the whole summary; now it is simply excluded
        let g = geomean(&[1.0, 0.0, 4.0, -2.0]);
        assert!((g - 2.0).abs() < 1e-12, "positive samples lost: {g}");
        // NaN fails the positivity test, so it is skipped too
        assert!((geomean(&[f64::NAN, 9.0]) - 9.0).abs() < 1e-12);
        // nothing positive left -> the "no samples" value, not a panic
        assert_eq!(geomean(&[0.0, -1.0]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression (ISSUE-8 satellite): one NaN used to panic the
        // `partial_cmp(..).unwrap()` sort — and with it every serving
        // metrics snapshot. NaN samples are dropped before ranking.
        let xs = [3.0, f64::NAN, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        // empty and all-NaN inputs report the "no samples" value
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn min_max_empty_input_is_zero_not_infinite() {
        // regression (ISSUE-8 satellite): empty input used to fold to
        // +/-inf, which callers then formatted as if it were a sample
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(min(&[3.0, 1.0, 2.0]), 1.0);
        assert_eq!(max(&[3.0, 1.0, 2.0]), 3.0);
        // NaN never wins the fold
        assert_eq!(min(&[f64::NAN, 5.0]), 5.0);
        assert_eq!(max(&[5.0, f64::NAN]), 5.0);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.p50() - 50.5).abs() < 1.0);
        assert!(h.p99() >= 99.0);
    }

    #[test]
    fn histogram_single_and_constant_streams_are_exact() {
        // single sample: every percentile clamps to the sample itself
        let mut h = Histogram::new();
        h.record(250.0);
        assert_eq!(h.p50(), 250.0);
        assert_eq!(h.p99(), 250.0);
        assert_eq!(h.mean(), 250.0);
        assert_eq!(h.max(), 250.0);
        // constant stream: min == max pins the estimate exactly
        let mut h = Histogram::new();
        for _ in 0..1000 {
            h.record(40.0);
        }
        assert_eq!(h.p50(), 40.0);
        assert_eq!(h.p99(), 40.0);
    }

    #[test]
    fn histogram_drops_nonfinite_and_buckets_extremes() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert!(h.is_empty());
        assert_eq!(h.p50(), 0.0);
        // zero / negative / beyond-range samples stay bounded and keep
        // percentiles inside the observed [min, max]
        h.record(0.0);
        h.record(-5.0);
        h.record(1e12);
        assert_eq!(h.len(), 3);
        assert_eq!(h.min(), -5.0);
        assert_eq!(h.max(), 1e12);
        let p = h.p50();
        assert!((-5.0..=1e12).contains(&p), "p50 escaped range: {p}");
    }

    /// ISSUE 9 satellite: a 10^6-sample stream must hold constant
    /// memory and keep p50/p99 within one bucket width (ratio
    /// 10^(1/25)) of the exact sorted-vector answer.
    #[test]
    fn histogram_memory_is_constant_and_percentiles_bucket_accurate() {
        use crate::util::rng::Pcg32;
        let mut h = Histogram::new();
        let mut rng = Pcg32::stream(0x1559, 9);
        let mut exact = Vec::with_capacity(1_000_000);
        let small = {
            let mut s = Histogram::new();
            s.record(1.0);
            s.memory_bytes()
        };
        for _ in 0..1_000_000 {
            // heavy-tailed latency-like stream spanning ~5 decades
            let u = rng.below(1_000_000) as f64 / 1_000_000.0;
            let v = 10.0 * (1.0 / (1.0 - u).max(1e-6)).powf(1.5);
            h.record(v);
            exact.push(v);
        }
        assert_eq!(h.len(), 1_000_000);
        // constant memory: identical to a 1-sample histogram, no heap
        assert_eq!(h.memory_bytes(), small);
        let ratio = 10f64.powf(1.0 / BUCKETS_PER_DECADE as f64);
        for p in [50.0, 99.0] {
            let est = h.percentile(p);
            let want = percentile(&exact, p);
            assert!(
                est >= want / ratio && est <= want * ratio,
                "p{p}: est {est} vs exact {want} beyond one bucket width"
            );
        }
        // mean stays exact (running sum), max is the true max
        assert!((h.mean() - mean(&exact)).abs() / mean(&exact) < 1e-9);
        assert_eq!(h.max(), max(&exact));
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
