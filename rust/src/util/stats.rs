//! Small statistics helpers used by the simulator, benches and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean — the paper reports geomean speedups across models.
/// Defined over the *positive* samples only: a zero/negative cell (a
/// degenerate sweep point, reachable from bench/report summaries) is
/// skipped rather than panicking the whole summary, and an input with
/// no positive sample reports 0.0 — the crate-wide "no samples"
/// convention. NaN fails the `> 0` test, so it is skipped too.
pub fn geomean(xs: &[f64]) -> f64 {
    let (sum, n) = xs
        .iter()
        .filter(|&&x| x > 0.0)
        .fold((0.0f64, 0usize), |(s, n), &x| (s + x.ln(), n + 1));
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).exp()
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation between closest ranks; `p` in
/// [0,100]. NaN samples are dropped before ranking (one NaN used to
/// panic the `partial_cmp(..).unwrap()` sort — and with it every
/// metrics snapshot at serve time); an empty or all-NaN input reports
/// 0.0, the same "no samples" convention the snapshot guards use.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Smallest non-NaN sample; 0.0 for empty (or all-NaN) input — callers
/// format these into reports, where a bare `inf` placeholder reads as
/// a real measurement.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .reduce(f64::min)
        .unwrap_or(0.0)
}

/// Largest non-NaN sample; 0.0 for empty (or all-NaN) input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .reduce(f64::max)
        .unwrap_or(0.0)
}

/// Simple latency/throughput histogram with fixed log-spaced buckets (ns).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn max(&self) -> f64 {
        max(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        // paper-style: geomean of speedups
        let g = geomean(&[1.59, 1.61, 1.57]);
        assert!((g - 1.59).abs() < 0.01);
    }

    #[test]
    fn geomean_skips_nonpositive_instead_of_panicking() {
        // regression (ISSUE-8 satellite): a degenerate sweep cell used
        // to assert-panic the whole summary; now it is simply excluded
        let g = geomean(&[1.0, 0.0, 4.0, -2.0]);
        assert!((g - 2.0).abs() < 1e-12, "positive samples lost: {g}");
        // NaN fails the positivity test, so it is skipped too
        assert!((geomean(&[f64::NAN, 9.0]) - 9.0).abs() < 1e-12);
        // nothing positive left -> the "no samples" value, not a panic
        assert_eq!(geomean(&[0.0, -1.0]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression (ISSUE-8 satellite): one NaN used to panic the
        // `partial_cmp(..).unwrap()` sort — and with it every serving
        // metrics snapshot. NaN samples are dropped before ranking.
        let xs = [3.0, f64::NAN, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        // empty and all-NaN inputs report the "no samples" value
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn min_max_empty_input_is_zero_not_infinite() {
        // regression (ISSUE-8 satellite): empty input used to fold to
        // +/-inf, which callers then formatted as if it were a sample
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(min(&[3.0, 1.0, 2.0]), 1.0);
        assert_eq!(max(&[3.0, 1.0, 2.0]), 3.0);
        // NaN never wins the fold
        assert_eq!(min(&[f64::NAN, 5.0]), 5.0);
        assert_eq!(max(&[5.0, f64::NAN]), 5.0);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.p50() - 50.5).abs() < 1.0);
        assert!(h.p99() >= 99.0);
    }

    #[test]
    fn stddev_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
