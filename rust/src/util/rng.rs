//! Deterministic PRNGs (no external `rand` crate in the offline image).
//!
//! [`SplitMix64`] seeds [`Pcg32`]; both are well-studied generators with
//! tiny state, more than adequate for synthetic weights, workload
//! generation and the property-test harness ([`crate::util::prop`]).

/// SplitMix64 — used for seeding and cheap one-off streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR): the main generator used across the repo.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state, inc };
        rng.next_u32(); // advance past the seed-correlated first output
        rng
    }

    /// Independent stream `i` derived from the same seed.
    pub fn stream(seed: u64, i: u64) -> Self {
        Self::new(seed ^ (0xA076_1D64_78BD_642F_u64.wrapping_mul(i + 1)))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free bias
    /// acceptable for our use; exact for bound << 2^32).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64() + 1e-12).min(1.0);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let va: Vec<u32> = (0..4).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..4).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg32::new(4);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::stream(9, 0);
        let mut b = Pcg32::stream(9, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
