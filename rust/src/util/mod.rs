//! Shared substrates: PRNG, JSON, statistics, property testing, CLI
//! parsing, bench harness and table rendering.
//!
//! These exist because the offline build image only vendors the `xla`
//! crate's dependency closure — `rand`, `serde`, `clap`, `criterion` and
//! `proptest` are unavailable, so the repo carries small, tested
//! equivalents (see DESIGN.md §1, substitution table).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
