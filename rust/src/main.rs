//! monarch-cim CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `figure <fig2b|tab1|fig6|fig7|fig8|adc-res|all>` — regenerate the
//!   paper's tables/figures (CSV copies land in `reports/`).
//! * `d2s [--d N] [--noise x]` — run the D2S projection on a synthetic
//!   dense matrix and report the Frobenius error.
//! * `map --model M --strategy S` — mapping statistics (Fig. 6 row).
//! * `simulate --model M --strategy S [--adcs N]` — latency/energy.
//! * `decode [--model tiny] [--strategy all] [--tokens 32]` — greedy
//!   autoregressive generation on the emulated CIM chip with per-token
//!   latency/energy, cross-checked against the factored reference model.
//! * `serve [--requests N] [--backend pjrt|cim-sim]` — batching-server
//!   demo (PJRT artifacts, or the CIM-sim backend with no artifacts).
//! * `serve-load [--workers W] [--clients N] [--requests R]` — serving
//!   load generator: concurrent ragged clients sharing a system-prompt
//!   prefix against the multi-worker CIM-sim server; SLO-grade metrics
//!   (TTFT / inter-token p99, prefix-cache hit rate, per-worker
//!   occupancy) land in `BENCH_serve.json`.
//! * `dse [--adc-bits 3,5,8] [--sigmas 0,0.01]` — analytic strategy/ADC
//!   sweep plus the measured accuracy-vs-energy-vs-latency frontier
//!   (noise/ADC-aware analog replay vs the exact chip), written to
//!   `BENCH_dse.json`; `--gate-ideal` makes zero-divergence-at-ideal a
//!   hard exit code (the CI gate).
//! * `e2e` — pipeline + runtime round-trip summary.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use monarch_cim::cim::CimParams;
use monarch_cim::coordinator::{
    run_pipeline, InferenceServer, PipelineConfig, ServerConfig, Tracer,
};
use monarch_cim::gpu::GpuParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::monarch::project_with_report;
use monarch_cim::report;
use monarch_cim::tensor::Matrix;
use monarch_cim::util::cli::Args;
use monarch_cim::util::rng::Pcg32;

fn usage() -> ! {
    eprintln!(
        "usage: monarch-cim <command>\n\
         commands:\n\
           figure <fig2b|tab1|fig6|fig7|fig8|adc-res|all> [--adcs 4,8,16,32]\n\
           d2s      [--d 1024] [--noise 0.02] [--seed N]\n\
           map      [--model bert|bart|gpt2] [--strategy linear|sparse|dense]\n\
           simulate [--model ...] [--strategy ...] [--adcs N]\n\
           decode   [--model tiny] [--strategy all|linear|sparse|dense]\n\
                    [--tokens N] [--prompt 4] [--seed 2025] [--adcs N]\n\
                    [--batch N]  (N>1: N concurrent streams, one chip)\n\
                    [--prefill-chunk C]  (chunked prompt ingestion, C\n\
                    positions per replay, cross-checked vs token-by-token)\n\
                    [--speculate-k K] [--draft-layers D]  (speculative\n\
                    decode: D-layer self-draft proposes K tokens/round,\n\
                    cross-checked bit-for-bit vs plain greedy)\n\
                    [--shards N]  (layer-sharded pipeline across N chips,\n\
                    cross-checked bit-for-bit vs the single-chip engine)\n\
                    [--noise-sigma S] [--drift-nu NU] [--drift-t-ratio R]\n\
                    [--adc-bits B] [--noise-seed N]  (analog realism: PCM\n\
                    write noise/drift corrupts the programmed cells, a\n\
                    B-bit SAR cap quantizes replay conversions; reports\n\
                    measured divergence vs the exact chip)\n\
                    [--trace-out FILE]  (Perfetto timeline of the modeled\n\
                    chip passes, one track per strategy)\n\
           serve    [--requests 64] [--artifacts DIR] [--backend pjrt|cim-sim]\n\
                    [--strategy dense] [--prefill-chunk C]\n\
                    [--speculate-k K] [--draft-layers D] [--shards N]\n\
                    [--workers W]  (W CIM-sim worker chips, shared queue)\n\
                    [--prefix-cache E]  (E shared-prefix KV entries per\n\
                    worker; 0 = off)\n\
                    [--trace-out FILE]  (Perfetto request/worker timeline,\n\
                    cim-sim backend only) [--stats-interval SECS]\n\
           serve-load [--workers 2] [--clients 32] [--requests 256]\n\
                    [--prefix P] [--prefix-cache 8] [--strategy dense]\n\
                    [--prefill-chunk C] [--shards N] [--seed 2025]\n\
                    [--out BENCH_serve.json] [--require-hits]\n\
                    [--trace-out FILE] [--stats-interval SECS]\n\
                    (ragged clients sharing a P-token system prompt;\n\
                    TTFT/inter-token p99 + prefix hit rate to JSON)\n\
           dse      [--model ...] [--adcs 1,4,8,16,32] [--budget N]\n\
                    [--adc-bits 3,5,8] [--sigmas 0,0.01] [--dse-tokens 8]\n\
                    [--seed 2025] [--noise-seed 2025]\n\
                    [--out BENCH_dse.json] [--gate-ideal]\n\
                    (measured accuracy-vs-energy-vs-latency frontier on a\n\
                    decoder-only model; --gate-ideal exits non-zero if an\n\
                    ideal point diverges — the CI smoke gate)\n\
           e2e      [--artifacts DIR]"
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "figure" => cmd_figure(&args),
        "d2s" => cmd_d2s(&args),
        "map" => cmd_map(&args),
        "simulate" => cmd_simulate(&args),
        "decode" => cmd_decode(&args),
        "serve" => cmd_serve(&args),
        "serve-load" => cmd_serve_load(&args),
        "dse" => cmd_dse(&args),
        "e2e" => cmd_e2e(&args),
        _ => usage(),
    }
}

fn model_of(args: &Args) -> ModelConfig {
    let name = args.str_or("model", "bert");
    ModelConfig::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown model '{name}' (bert|bart|gpt2|tiny)");
        std::process::exit(2);
    })
}

fn strategy_of(args: &Args) -> Strategy {
    let name = args.str_or("strategy", "dense");
    Strategy::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown strategy '{name}' (linear|sparse|dense)");
        std::process::exit(2);
    })
}

fn cmd_figure(args: &Args) {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let params = CimParams::default();
    let gpu = GpuParams::default();
    let adcs = args.usize_list_or("adcs", &[1, 4, 8, 16, 32]);
    let run = |id: &str| match id {
        "fig2b" => {
            println!("Fig. 2b — parameter & FLOP reduction (D2S):");
            report::fig2b().print();
        }
        "tab1" => {
            println!("Table I — CIM cost parameters:");
            report::tab1(&params).print();
        }
        "fig6" => {
            println!("Fig. 6 — CIM arrays & utilization per mapping:");
            report::fig6(&params).print();
        }
        "fig7" => {
            println!("Fig. 7 — latency & energy per configuration:");
            report::fig7(&params, &gpu).print();
        }
        "fig8" => {
            println!("Fig. 8 — ADC sharing DSE (BERT):");
            report::fig8(&adcs).print();
        }
        "adc-res" => {
            println!("§IV-C — ADC resolution scaling:");
            report::adc_resolution(&params).print();
        }
        other => {
            eprintln!("unknown figure '{other}'");
            std::process::exit(2);
        }
    };
    if which == "all" {
        for id in ["fig2b", "tab1", "fig6", "fig7", "fig8", "adc-res"] {
            run(id);
        }
    } else {
        run(which);
    }
    println!("(CSV copies written to reports/)");
}

fn cmd_d2s(args: &Args) {
    let d = args.usize_or("d", 1024);
    let noise = args.f64_or("noise", 0.02) as f32;
    let seed = args.usize_or("seed", 2025) as u64;
    let b = (d as f64).sqrt().round() as usize;
    if b * b != d {
        eprintln!("--d must be a perfect square");
        std::process::exit(2);
    }
    let mut rng = Pcg32::new(seed);
    let base = monarch_cim::monarch::MonarchMatrix::randn(b, &mut rng)
        .to_dense()
        .scale(1.0 / b as f32);
    let w = base.add(&Matrix::randn(d, d, &mut rng).scale(noise));
    let t0 = std::time::Instant::now();
    let (m, rep) = project_with_report(&w);
    println!(
        "D2S projection of a near-Monarch {d}x{d} (noise {noise}):\n  \
         rel. Frobenius error: {:.4}\n  worst slice error: {:.4}\n  \
         params: {} -> {} ({:.1}x)\n  projection time: {:?}",
        rep.rel_error,
        rep.worst_slice_error,
        d * d,
        m.params(),
        (d * d) as f64 / m.params() as f64,
        t0.elapsed()
    );
}

fn cmd_map(args: &Args) {
    let cfg = PipelineConfig {
        model: model_of(args),
        strategy: strategy_of(args),
        cim: CimParams::default(),
        d2s_numeric_check: false,
        seed: 2025,
    };
    let r = run_pipeline(&cfg);
    println!(
        "{} / {}: {} arrays, utilization {:.1}%, weight memory {:.1} MiB, placements {}",
        r.mapping.model,
        r.mapping.strategy.name(),
        r.mapping.arrays,
        100.0 * r.mapping.utilization(),
        r.mapping_stats.memory_mib,
        r.mapping.placements.len()
    );
}

fn cmd_simulate(args: &Args) {
    let mut cim = CimParams::default();
    if args.has("adcs") {
        cim = cim.with_adcs_per_array(args.usize_or("adcs", 1));
    }
    let cfg = PipelineConfig {
        model: model_of(args),
        strategy: strategy_of(args),
        cim,
        d2s_numeric_check: false,
        seed: 2025,
    };
    let r = run_pipeline(&cfg);
    let c = &r.cost;
    println!(
        "{} / {} @ {} ADC/array ({}b ADC):\n  \
         latency: {:.3} ms ({} tokens; {:.2} µs/token)\n  \
         energy:  {:.2} mJ\n  \
         breakdown/token: analog {:.0} ns, adc {:.0} ns, comm {:.0} ns (pipelined), dpu {:.0} ns (pipelined)",
        c.model,
        c.strategy.name(),
        c.adcs_per_array,
        c.adc_bits,
        c.latency_ms(),
        c.seq,
        c.per_token.latency.critical_ns() / 1e3,
        c.energy_mj(),
        c.per_token.latency.analog_ns,
        c.per_token.latency.adc_ns,
        c.per_token.latency.comm_ns,
        c.per_token.latency.dpu_ns,
    );
}

fn cmd_decode(args: &Args) {
    use monarch_cim::cim::{AnalogMode, PcmNoise};
    use monarch_cim::sim::decode::{BatchDecodeEngine, DecodeEngine, DecodeModel};
    use monarch_cim::sim::measure_divergence;
    use monarch_cim::sim::speculate::{
        self_draft_layers, self_draft_model, SpeculativeEngine,
    };
    let cfg = model_of_decoder(args);
    let prompt_len = args.usize_or("prompt", 4).max(1);
    if prompt_len >= cfg.seq {
        eprintln!(
            "error: --prompt {prompt_len} leaves no room to generate within the \
             context window (seq={})",
            cfg.seq
        );
        std::process::exit(2);
    }
    // default generation length fills the window; an explicit request
    // beyond it is rejected at admission (no silent position clamping)
    let n_tokens = args.usize_or("tokens", 32.min(cfg.seq - prompt_len));
    if prompt_len + n_tokens > cfg.seq {
        eprintln!(
            "error: prompt {prompt_len} + {n_tokens} generated tokens exceed the \
             context window (seq={}); pass --tokens <= {}",
            cfg.seq,
            cfg.seq - prompt_len
        );
        std::process::exit(2);
    }
    let batch = args.usize_or("batch", 1).max(1);
    let prefill_chunk = args.usize_or("prefill-chunk", 1).max(1);
    let speculate_k = args.usize_or("speculate-k", 0);
    let draft_layers = args.usize_or("draft-layers", 0);
    let shards = args.usize_or("shards", 1).max(1);
    let seed = args.usize_or("seed", 2025) as u64;
    // opt-in analog realism (DESIGN.md §6i): PCM write noise/drift
    // corrupt the programmed cells; an ADC cap quantizes replay
    // conversions. Absent flags keep the exact bit-identical path.
    let noise_sigma = args.f64_or("noise-sigma", 0.0);
    let drift_nu = args.f64_or("drift-nu", 0.0);
    let drift_t_ratio = args.f64_or("drift-t-ratio", 1.0e4);
    let adc_cap = args
        .has("adc-bits")
        .then(|| args.usize_or("adc-bits", 8) as u32);
    let noise_seed = args.usize_or("noise-seed", 2025) as u64;
    let analog_mode = (noise_sigma > 0.0 || drift_nu > 0.0 || adc_cap.is_some()).then(|| {
        AnalogMode {
            noise: PcmNoise {
                write_sigma: noise_sigma,
                drift_nu,
                drift_time_ratio: drift_t_ratio,
            },
            adc_bits: adc_cap,
            seed: noise_seed,
        }
    });
    let mut cim = CimParams::default();
    if args.has("adcs") {
        cim = cim.with_adcs_per_array(args.usize_or("adcs", 1));
    }
    let strategies: Vec<Strategy> = match args.str_or("strategy", "all").as_str() {
        "all" => Strategy::all().to_vec(),
        s => vec![Strategy::by_name(s).unwrap_or_else(|| {
            eprintln!("unknown strategy '{s}' (all|linear|sparse|dense)");
            std::process::exit(2);
        })],
    };
    let prompt: Vec<i32> = (0..prompt_len)
        .map(|i| ((i * 37 + 11) % cfg.vocab) as i32)
        .collect();

    println!(
        "autoregressive decode: {} ({} layers, d={}, vocab={}), prompt {:?}, {} tokens",
        cfg.name, cfg.dec_layers, cfg.d_model, cfg.vocab, prompt, n_tokens
    );
    let mut reference = DecodeEngine::reference(DecodeModel::synth(cfg.clone(), seed));
    let golden = reference.generate(&prompt, n_tokens);
    println!("reference (factored Monarch matvec): {:?}", golden.tokens);

    // --trace-out: per-strategy modeled chip-pass timelines for Perfetto
    let mut trace_runs: Vec<(String, Vec<monarch_cim::cim::Cost>)> = Vec::new();

    for &strategy in &strategies {
        let mut eng =
            DecodeEngine::on_chip(DecodeModel::synth(cfg.clone(), seed), cim.clone(), strategy);
        let t0 = std::time::Instant::now();
        let r = eng.generate(&prompt, n_tokens);
        let wall = t0.elapsed();
        let mapping_arrays = eng.mapping().map(|m| m.arrays).unwrap_or(0);
        // generate moves the run's trace into the result
        let total = r.total();
        if args.has("trace-out") {
            trace_runs.push((strategy.name().to_string(), r.per_token.clone()));
        }
        println!(
            "\n{} — {} arrays, {} generated tokens in {:.2?} wall ({} chip passes modeled):",
            strategy.name(),
            mapping_arrays,
            r.tokens.len(),
            wall,
            r.per_token.len(),
        );
        println!("  tokens: {:?}", r.tokens);
        println!("  tok  latency(µs)  energy(nJ)   mha(ns)");
        for (i, c) in r.per_token.iter().enumerate().skip(prompt_len) {
            println!(
                "  {:>3}  {:>11.3}  {:>10.1}  {:>8.0}",
                i - prompt_len,
                c.latency.critical_ns() / 1e3,
                c.energy.total_nj(),
                c.latency.mha_ns,
            );
        }
        println!(
            "  totals: {:.3} µs latency, {:.1} nJ energy, mean {:.3} µs/token",
            total.latency.critical_ns() / 1e3,
            total.energy.total_nj(),
            total.latency.critical_ns() / r.per_token.len().max(1) as f64 / 1e3,
        );
        // numeric agreement vs the reference model over the same window
        let window: Vec<i32> = prompt.iter().chain(&r.tokens).copied().collect();
        let (chip_logits, _) = eng.score(&window);
        let (ref_logits, _) = reference.score(&window);
        let max_diff = chip_logits
            .iter()
            .zip(&ref_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let tokens_match = r.tokens == golden.tokens;
        println!(
            "  vs reference: tokens {} | max |logit diff| = {:.3e} {}",
            if tokens_match { "IDENTICAL" } else { "MISMATCH" },
            max_diff,
            if strategy == Strategy::Linear {
                "(dense baseline: float-tolerance expected)"
            } else if max_diff <= 1e-5 {
                "(<= 1e-5 OK)"
            } else {
                "(EXCEEDS 1e-5)"
            },
        );
        if let Some(mode) = &analog_mode {
            // analog replay on the same model/strategy: generate under
            // noise + cap, then measure teacher-forced divergence vs
            // the exact chip engine over the reference window
            let mut analog = DecodeEngine::on_chip_analog(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
                Some(mode),
            );
            let ar = analog.generate(&prompt, n_tokens);
            println!(
                "  analog replay (sigma={noise_sigma}, nu={drift_nu}, t/t0={drift_t_ratio}, adc={}):",
                mode.adc_bits
                    .map(|b| format!("{b}b"))
                    .unwrap_or_else(|| "exact".into()),
            );
            println!("    tokens: {:?}", ar.tokens);
            let d = measure_divergence(&mut eng, &mut analog, &window);
            println!(
                "    divergence vs exact chip ({} forced positions): first {} | agreement {:.3} | max|dlogit| {:.3e} | rms {:.3e} | dppl {:+.4e}",
                d.positions,
                d.first_divergence
                    .map(|p| p.to_string())
                    .unwrap_or_else(|| "none".into()),
                d.token_agreement,
                d.max_abs_logit_err,
                d.rms_logit_err,
                d.ppl_delta,
            );
        }
    }

    if batch > 1 {
        println!("\nbatched decode ({batch} concurrent streams, one chip):");
        // distinct prompts per stream (stream 0 = the single-stream prompt)
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|s| {
                (0..prompt_len)
                    .map(|i| ((i * 37 + 11 + s * 101) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        for &strategy in &strategies {
            let mut be = BatchDecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
                batch,
            );
            let t0 = std::time::Instant::now();
            let results = be.generate_batch(&prompts, n_tokens);
            let wall = t0.elapsed();
            let total_positions: usize =
                results.iter().map(|r| r.per_token.len()).sum();
            let tps = total_positions as f64 / wall.as_secs_f64();
            // every stream must match an independent single-stream run;
            // one engine suffices — generate() resets between requests
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
            );
            let mut identical = true;
            for (p, r) in prompts.iter().zip(&results) {
                if single.generate(p, n_tokens).tokens != r.tokens {
                    identical = false;
                }
            }
            println!(
                "  {:<7} {} streams x {} tokens in {:.2?} wall = {:.0} tokens/s | vs single-stream: {}",
                strategy.name(),
                batch,
                n_tokens,
                wall,
                tps,
                if identical { "IDENTICAL" } else { "MISMATCH" },
            );
            for (s, r) in results.iter().enumerate() {
                println!("    stream {s}: {:?}", r.tokens);
            }
        }
    }

    if prefill_chunk > 1 {
        // Chunked prefill cross-check mode: ingest the prompt C
        // positions per replay (sim::prefill), then verify the chunked
        // run against the token-by-token reference engine — tokens must
        // be identical for every strategy and chunk size.
        println!(
            "\nchunked prefill ({prefill_chunk} positions per replay, {batch} stream{}):",
            if batch == 1 { "" } else { "s" }
        );
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|s| {
                (0..prompt_len)
                    .map(|i| ((i * 37 + 11 + s * 101) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        for &strategy in &strategies {
            let mut be = BatchDecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
                batch,
            );
            let t0 = std::time::Instant::now();
            let chunked = be.generate_batch_chunked(&prompts, n_tokens, prefill_chunk);
            let wall = t0.elapsed();
            let t1 = std::time::Instant::now();
            let token_by_token = be.generate_batch_chunked(&prompts, n_tokens, 1);
            let wall1 = t1.elapsed();
            // cross-check: the token-by-token single-stream engine is
            // the reference chunking must reproduce bit for bit
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
            );
            let mut identical = true;
            for (p, r) in prompts.iter().zip(&chunked) {
                if single.generate(p, n_tokens).tokens != r.tokens {
                    identical = false;
                }
            }
            for (a, b) in chunked.iter().zip(&token_by_token) {
                if a.tokens != b.tokens {
                    identical = false;
                }
            }
            println!(
                "  {:<7} chunk={prefill_chunk}: {:.2?} wall vs chunk=1: {:.2?} ({:.2}x) | vs reference: {}",
                strategy.name(),
                wall,
                wall1,
                wall1.as_secs_f64() / wall.as_secs_f64().max(1e-12),
                if identical { "IDENTICAL" } else { "MISMATCH" },
            );
        }
    }

    if speculate_k > 0 {
        // Speculative decode cross-check mode: a layer-truncated
        // self-draft proposes K tokens per round, the target verifies
        // all K+1 positions in one batched replay (sim::speculate), and
        // the emitted sequence is checked bit-for-bit against plain
        // greedy decode — the ISSUE-5 guarantee, live on the CLI.
        println!(
            "\nspeculative decode (K={speculate_k} proposals/round, {}-layer self-draft):",
            self_draft_layers(&cfg, draft_layers)
        );
        for &strategy in &strategies {
            let mut spec = SpeculativeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                self_draft_model(&cfg, seed, draft_layers),
                cim.clone(),
                strategy,
                speculate_k,
            );
            let t0 = std::time::Instant::now();
            let r = spec.generate(&prompt, n_tokens);
            let wall = t0.elapsed();
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
            );
            let want = single.generate(&prompt, n_tokens);
            let identical = r.tokens == want.tokens;
            // modeled generation-phase latency: plain serial decode vs
            // pipelined verify rounds + serial draft forwards
            let plain_ns: f64 = want.per_token[prompt_len..]
                .iter()
                .map(|c| c.latency.critical_ns())
                .sum();
            let spec_ns = r.modeled_generation_ns();
            println!(
                "  {:<7} {} rounds, acceptance {:.2}, {:.2} tokens/round | modeled speedup {:.2}x | {:.2?} wall | vs plain greedy: {}",
                strategy.name(),
                r.rounds.len(),
                r.acceptance_rate(),
                r.tokens_per_round(),
                plain_ns / spec_ns.max(1e-12),
                wall,
                if identical { "IDENTICAL" } else { "MISMATCH" },
            );
            println!("    tokens: {:?}", r.tokens);
        }
    }

    if shards > 1 {
        // Layer-sharded pipeline cross-check mode (sim::shard): the
        // decoder's layers run across N stage chips with in-flight
        // microbatches; tokens must be bit-identical to the single-chip
        // engine for every strategy, and the per-stage timeline reports
        // the modeled pipeline win.
        println!(
            "\nlayer-sharded pipeline ({shards} chips, {batch} in-flight stream{}):",
            if batch == 1 { "" } else { "s" }
        );
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|s| {
                (0..prompt_len)
                    .map(|i| ((i * 37 + 11 + s * 101) % cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        for &strategy in &strategies {
            let mut sharded = BatchDecodeEngine::sharded(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
                batch,
                shards,
            );
            let t0 = std::time::Instant::now();
            let piped = sharded.generate_batch_chunked(&prompts, n_tokens, prefill_chunk);
            let wall = t0.elapsed();
            let mut mono = BatchDecodeEngine::on_chip(
                DecodeModel::synth(cfg.clone(), seed),
                cim.clone(),
                strategy,
                batch,
            );
            let want = mono.generate_batch_chunked(&prompts, n_tokens, prefill_chunk);
            let identical = piped
                .iter()
                .zip(&want)
                .all(|(a, b)| a.tokens == b.tokens);
            let ps = sharded.pipeline_stats();
            let ranges = sharded
                .stage_ranges()
                .iter()
                .map(|&(lo, hi)| format!("[{lo}..{hi})"))
                .collect::<Vec<_>>()
                .join(" ");
            println!(
                "  {:<7} {} stages {} | modeled speedup {:.2}x, bubble {:.2}, occupancy {} | {:.2?} wall | vs single chip: {}",
                strategy.name(),
                sharded.stage_count(),
                ranges,
                ps.speedup_vs_1chip(),
                ps.bubble_fraction(),
                ps.stage_occupancy()
                    .iter()
                    .map(|o| format!("{o:.2}"))
                    .collect::<Vec<_>>()
                    .join("/"),
                wall,
                if identical { "IDENTICAL" } else { "MISMATCH" },
            );
            for (s, r) in piped.iter().enumerate() {
                println!("    stream {s}: {:?}", r.tokens);
            }
        }
    }

    if let Some(path) = args.get("trace-out") {
        // modeled sim-time timeline: one Perfetto track per strategy,
        // one span per chip pass (coordinator::tracing)
        let doc = monarch_cim::coordinator::tracing::decode_timeline_json(&trace_runs);
        if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
        println!("\nwrote {path} — load in ui.perfetto.dev or chrome://tracing");
    }
}

/// Export one collected serving trace: Perfetto trace-event JSON to
/// `path` (compact form — traces get large) plus the per-request
/// breakdown table on stdout. Call after `shutdown()`, when every
/// worker has delivered its event ring.
fn export_trace(tracer: &Tracer, path: &str) {
    use monarch_cim::coordinator::tracing::{breakdown_table, perfetto_json};
    let events = tracer.events();
    let doc = perfetto_json(&events);
    if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
        eprintln!("failed to write {path}: {e}");
        std::process::exit(1);
    }
    let dropped = tracer.dropped();
    println!(
        "wrote {path} ({} events{}) — load in ui.perfetto.dev or chrome://tracing",
        events.len(),
        if dropped > 0 {
            format!(", {dropped} overwritten by the ring bound")
        } else {
            String::new()
        }
    );
    println!("per-request breakdown (TTFT = queue µs + prefill µs):");
    print!("{}", breakdown_table(&events, 32));
}

/// Periodic one-line serving snapshot (`--stats-interval SECS`): spawned
/// into the caller's outer scope; exits when the caller flips `stop`
/// after its clients drain (short sleep slices keep shutdown prompt).
fn spawn_stats_printer<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    server: &'env InferenceServer,
    stop: &'env AtomicBool,
    interval_s: f64,
) {
    scope.spawn(move || loop {
        let mut slept = 0.0;
        while slept < interval_s {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
            slept += 0.05;
        }
        let s = server.metrics.snapshot();
        println!(
            "[stats] {:.1} req/s | {:.1} tok/s | occupancy {:.2} of {} | queue {} | prefix hit {:.2} | cancelled {}",
            s.throughput_rps,
            s.sim_tokens_per_sec,
            s.occupancy_mean,
            s.slot_capacity,
            server.queue_depth(),
            s.prefix_hit_rate,
            s.cancellations
        );
    });
}

fn model_of_decoder(args: &Args) -> ModelConfig {
    let name = args.str_or("model", "tiny");
    let cfg = ModelConfig::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown model '{name}' (tiny|gpt2)");
        std::process::exit(2);
    });
    if cfg.enc_layers != 0 || cfg.dec_layers == 0 {
        eprintln!("decode needs a decoder-only model; '{name}' is not");
        std::process::exit(2);
    }
    cfg
}

fn cmd_serve(args: &Args) {
    let n = args.usize_or("requests", 64);
    let mut cfg = ServerConfig::default();
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    let trace_out = args.get("trace-out").map(String::from);
    let mut tracer: Option<Arc<Tracer>> = None;
    let backend_name = args.str_or("backend", "pjrt");
    match backend_name.as_str() {
        "pjrt" => {}
        "cim-sim" | "cimsim" | "sim" => {
            let name = args.str_or("strategy", "dense");
            let strategy = Strategy::by_name(&name).unwrap_or_else(|| {
                eprintln!("unknown strategy '{name}' (linear|sparse|dense)");
                std::process::exit(2);
            });
            cfg = ServerConfig::cim_sim(strategy);
            // chunked prompt ingestion width (0 = auto from the batch
            // lane budget — the slot capacity) and speculation knobs
            // (0 = off; draft-layers 0 = full-depth self-draft)
            if let monarch_cim::coordinator::Backend::CimSim(sim) = &mut cfg.backend {
                sim.prefill_chunk = args.usize_or("prefill-chunk", 0);
                sim.speculate_k = args.usize_or("speculate-k", 0);
                sim.draft_layers = args.usize_or("draft-layers", 0);
                sim.shards = args.usize_or("shards", 1);
                sim.workers = args.usize_or("workers", 1);
                sim.prefix_cache = args.usize_or("prefix-cache", 0);
                if trace_out.is_some() {
                    let t = Arc::new(Tracer::new(65536));
                    sim.trace = Some(t.clone());
                    tracer = Some(t);
                }
            }
        }
        other => {
            eprintln!("unknown backend '{other}' (pjrt|cim-sim)");
            std::process::exit(2);
        }
    }
    println!("starting batching inference server ({backend_name})...");
    let server = match InferenceServer::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server failed to start: {e:#}");
            std::process::exit(1);
        }
    };
    let seq = server.seq;
    let vocab = server.vocab as i32;
    let stats_interval = args.f64_or("stats-interval", 0.0);
    let stop = AtomicBool::new(false);
    let t0 = std::time::Instant::now();
    std::thread::scope(|outer| {
        if stats_interval > 0.0 {
            spawn_stats_printer(outer, &server, &stop, stats_interval);
        }
        std::thread::scope(|scope| {
            for i in 0..n {
                let srv = &server;
                scope.spawn(move || {
                    let mut rng = Pcg32::new(i as u64);
                    let toks: Vec<i32> =
                        (0..seq).map(|_| rng.below(vocab as u32) as i32).collect();
                    let r = srv.infer(toks);
                    assert!(r.is_ok(), "request {i} failed: {:?}", r.err());
                });
            }
        });
        stop.store(true, Ordering::Relaxed);
    });
    let elapsed = t0.elapsed();
    let s = server.metrics.snapshot();
    println!(
        "served {} requests in {:.2?}: {:.1} req/s, mean batch {:.2}, p50 {:.1} µs, p99 {:.1} µs, errors {}",
        s.requests, elapsed, s.throughput_rps, s.mean_batch, s.latency_p50_us, s.latency_p99_us, s.errors
    );
    if s.sim_tokens > 0 {
        println!(
            "cim-sim chip model: {} tokens, {:.3} µs/token latency, {:.2} µJ total energy",
            s.sim_tokens,
            s.sim_token_latency_ns / 1e3,
            s.sim_energy_nj / 1e3
        );
        println!(
            "continuous batching: {:.1} tokens/s wall, occupancy mean {:.2} / peak {} of {} slots",
            s.sim_tokens_per_sec, s.occupancy_mean, s.occupancy_peak, s.slot_capacity
        );
        println!(
            "request phases: TTFT p50 {:.1} µs / p99 {:.1} µs, inter-token p50 {:.1} µs / p99 {:.1} µs",
            s.ttft_p50_us, s.ttft_p99_us, s.inter_token_p50_us, s.inter_token_p99_us
        );
        if s.prefill_chunks > 0 {
            println!(
                "chunked prefill: {} positions over {} multi-position replays (mean chunk {:.1})",
                s.prefill_positions,
                s.prefill_chunks,
                s.prefill_positions as f64 / s.prefill_chunks as f64
            );
        }
        if s.spec_rounds > 0 {
            println!(
                "speculation: {} verify rounds, acceptance {:.2}, {:.2} tokens/round",
                s.spec_rounds, s.spec_acceptance_rate, s.spec_tokens_per_round
            );
        }
        if s.pipeline_steps > 0 {
            println!(
                "pipeline: {} stages over {} steps, modeled speedup {:.2}x, bubble {:.2}, stage occupancy {}",
                s.shard_stages,
                s.pipeline_steps,
                s.pipeline_speedup,
                s.pipeline_bubble_fraction,
                s.stage_occupancy
                    .iter()
                    .map(|o| format!("{o:.2}"))
                    .collect::<Vec<_>>()
                    .join("/")
            );
        }
        if s.workers > 1 {
            println!(
                "workers: {} chips, per-worker occupancy {}",
                s.workers,
                s.worker_occupancy
                    .iter()
                    .map(|o| format!("{o:.2}"))
                    .collect::<Vec<_>>()
                    .join("/")
            );
        }
        if s.prefix_lookups > 0 {
            println!(
                "prefix cache: {}/{} hits ({:.2}), {} prompt positions skipped prefill",
                s.prefix_hits, s.prefix_lookups, s.prefix_hit_rate, s.prefix_positions_saved
            );
        }
        if s.cancellations > 0 {
            println!("cancellations: {} abandoned requests released early", s.cancellations);
        }
    }
    server.shutdown();
    if let Some(path) = &trace_out {
        match &tracer {
            // export after shutdown: every worker delivered its ring
            Some(t) => export_trace(t, path),
            None => eprintln!("--trace-out ignored: tracing needs --backend cim-sim"),
        }
    }
}

/// Serving load generator (DESIGN.md §6g): `--clients` concurrent
/// threads fire `--requests` total ragged windows at a `--workers`-chip
/// CIM-sim server. Every window opens with the same `--prefix`-token
/// system prompt (deterministic from `--seed`) followed by a ragged
/// random tail, so a warm shared-prefix cache should answer the prompt
/// positions without replaying them. SLO-grade results — TTFT and
/// inter-token p50/p99, prefix hit rate, positions saved, per-worker
/// occupancy, cancellations — print to stdout and land as JSON in
/// `--out` (default `BENCH_serve.json`). `--require-hits` exits
/// non-zero when the prefix cache never hit (the CI smoke gate).
fn cmd_serve_load(args: &Args) {
    use monarch_cim::util::json::{arr, num, obj, s as js};
    let workers = args.usize_or("workers", 2);
    let clients = args.usize_or("clients", 32);
    let total = args.usize_or("requests", 256);
    let seed = args.usize_or("seed", 2025) as u64;
    let name = args.str_or("strategy", "dense");
    let strategy = Strategy::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown strategy '{name}' (linear|sparse|dense)");
        std::process::exit(2);
    });
    let trace_out = args.get("trace-out").map(String::from);
    let mut tracer: Option<Arc<Tracer>> = None;
    let mut cfg = ServerConfig::cim_sim(strategy);
    if let monarch_cim::coordinator::Backend::CimSim(sim) = &mut cfg.backend {
        sim.workers = workers;
        sim.prefix_cache = args.usize_or("prefix-cache", 8);
        sim.prefill_chunk = args.usize_or("prefill-chunk", 0);
        sim.speculate_k = args.usize_or("speculate-k", 0);
        sim.draft_layers = args.usize_or("draft-layers", 0);
        sim.shards = args.usize_or("shards", 1);
        sim.seed = seed;
        if trace_out.is_some() {
            let t = Arc::new(Tracer::new(65536));
            sim.trace = Some(t.clone());
            tracer = Some(t);
        }
    }
    println!("starting {workers}-worker cim-sim server ({name} mapping)...");
    let server = match InferenceServer::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server failed to start: {e:#}");
            std::process::exit(1);
        }
    };
    let seq = server.seq;
    let vocab = server.vocab as u32;
    // shared system prompt: deterministic from the seed, so every
    // client's window opens identically (the prefix-cache workload)
    let prefix_len = args.usize_or("prefix", seq / 2).min(seq - 1);
    let mut prng = Pcg32::new(seed);
    let prefix: Vec<i32> = (0..prefix_len).map(|_| prng.below(vocab) as i32).collect();
    let stats_interval = args.f64_or("stats-interval", 0.0);
    let stop = AtomicBool::new(false);
    let t0 = std::time::Instant::now();
    std::thread::scope(|outer| {
        if stats_interval > 0.0 {
            spawn_stats_printer(outer, &server, &stop, stats_interval);
        }
        std::thread::scope(|scope| {
            for c in 0..clients {
                let srv = &server;
                let prefix = &prefix;
                // client c serves request indices c, c+clients, c+2*clients, …
                scope.spawn(move || {
                    let mut rng = Pcg32::new(seed ^ (0x9e37 + c as u64));
                    let mut i = c;
                    while i < total {
                        let tail = 1 + rng.below((seq - prefix.len()) as u32) as usize;
                        let mut toks = prefix.clone();
                        toks.extend((0..tail).map(|_| rng.below(vocab) as i32));
                        let r = srv.infer(toks);
                        assert!(r.is_ok(), "request {i} failed: {:?}", r.err());
                        i += clients;
                    }
                });
            }
        });
        stop.store(true, Ordering::Relaxed);
    });
    let elapsed = t0.elapsed();
    let snap = server.metrics.snapshot();
    println!(
        "served {} requests from {} clients in {:.2?}: {:.1} req/s, errors {}",
        snap.requests, clients, elapsed, snap.throughput_rps, snap.errors
    );
    println!(
        "request phases: TTFT p50 {:.1} µs / p99 {:.1} µs, inter-token p50 {:.1} µs / p99 {:.1} µs",
        snap.ttft_p50_us, snap.ttft_p99_us, snap.inter_token_p50_us, snap.inter_token_p99_us
    );
    println!(
        "prefix cache: {}/{} hits ({:.2}), {} of {} chip positions skipped prefill",
        snap.prefix_hits,
        snap.prefix_lookups,
        snap.prefix_hit_rate,
        snap.prefix_positions_saved,
        snap.prefix_positions_saved + snap.sim_tokens
    );
    println!(
        "workers: {} chips, per-worker occupancy {} (aggregate mean {:.2} / peak {} of {} slots)",
        snap.workers,
        snap.worker_occupancy
            .iter()
            .map(|o| format!("{o:.2}"))
            .collect::<Vec<_>>()
            .join("/"),
        snap.occupancy_mean,
        snap.occupancy_peak,
        snap.slot_capacity
    );
    if snap.cancellations > 0 {
        println!("cancellations: {}", snap.cancellations);
    }
    let out = args.str_or("out", "BENCH_serve.json");
    let json = obj(vec![
        ("bench", js("serve_load")),
        ("strategy", js(&name)),
        ("workers", num(snap.workers as f64)),
        ("clients", num(clients as f64)),
        ("requests", num(snap.requests as f64)),
        ("errors", num(snap.errors as f64)),
        ("cancellations", num(snap.cancellations as f64)),
        ("elapsed_s", num(elapsed.as_secs_f64())),
        ("throughput_rps", num(snap.throughput_rps)),
        ("ttft_p50_us", num(snap.ttft_p50_us)),
        ("ttft_p99_us", num(snap.ttft_p99_us)),
        ("inter_token_p50_us", num(snap.inter_token_p50_us)),
        ("inter_token_p99_us", num(snap.inter_token_p99_us)),
        ("prefix_lookups", num(snap.prefix_lookups as f64)),
        ("prefix_hits", num(snap.prefix_hits as f64)),
        ("prefix_hit_rate", num(snap.prefix_hit_rate)),
        ("prefix_positions_saved", num(snap.prefix_positions_saved as f64)),
        ("sim_tokens", num(snap.sim_tokens as f64)),
        ("sim_tokens_per_sec", num(snap.sim_tokens_per_sec)),
        (
            "worker_occupancy",
            arr(snap.worker_occupancy.iter().map(|&o| num(o))),
        ),
    ]);
    if let Err(e) = std::fs::write(&out, format!("{json}\n")) {
        eprintln!("failed to write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out}");
    server.shutdown();
    if let (Some(path), Some(t)) = (&trace_out, &tracer) {
        // export after shutdown: every worker delivered its ring
        export_trace(t, path);
    }
    if args.has("require-hits") && snap.prefix_hits == 0 {
        eprintln!("FAIL: prefix cache never hit under a shared-prefix workload");
        std::process::exit(1);
    }
}

fn cmd_dse(args: &Args) {
    use monarch_cim::coordinator::dse::{best, explore, explore_measured};
    use monarch_cim::mapping::constrained::WriteCosts;
    use monarch_cim::util::json::{arr, num, obj, s as js, Json};
    let model = model_of(args);
    let adcs = args.usize_list_or("adcs", &[1, 4, 8, 16, 32]);
    let budget = args.get("budget").map(|_| args.usize_or("budget", 512));
    let pts = explore(&model, &adcs, budget, &WriteCosts::default());
    println!(
        "DSE for {} (budget: {}):",
        model.name,
        budget.map(|b| b.to_string()).unwrap_or_else(|| "unconstrained".into())
    );
    let mut t = monarch_cim::util::table::Table::new([
        "strategy", "ADCs", "arrays", "fits", "µs/token", "energy (mJ)", "ADC bits",
    ]);
    for p in &pts {
        t.row([
            p.strategy.name().to_string(),
            p.adcs_per_array.to_string(),
            p.arrays.to_string(),
            if p.fits_budget { "yes".into() } else { "NO".to_string() },
            format!("{:.2}", p.token_latency_ns / 1e3),
            format!("{:.2}", p.energy_mj),
            p.adc_bits.to_string(),
        ]);
    }
    t.print();
    if let Some(b) = best(&pts) {
        println!(
            "best: {} @ {} ADCs/array ({:.2} µs/token)",
            b.strategy.name(),
            b.adcs_per_array,
            b.token_latency_ns / 1e3
        );
    }

    // Measured accuracy-vs-energy-vs-latency frontier (DESIGN.md §6i):
    // needs a decoder-only model to replay — fall back to tiny when the
    // analytic sweep targeted an encoder config.
    let frontier_cfg = if model.enc_layers == 0 && model.dec_layers > 0 {
        model.clone()
    } else {
        println!(
            "\n'{}' is not decoder-only; measuring the analog frontier on 'tiny'",
            model.name
        );
        ModelConfig::tiny()
    };
    let params = CimParams::default();
    let caps: Vec<Option<u32>> = std::iter::once(None)
        .chain(
            args.usize_list_or("adc-bits", &[3, 5, 8])
                .into_iter()
                .map(|b| Some(b as u32)),
        )
        .collect();
    let sigmas = args.f64_list_or("sigmas", &[0.0, 0.01]);
    let window = args.usize_or("dse-tokens", 8).clamp(2, frontier_cfg.seq);
    let model_seed = args.usize_or("seed", 2025) as u64;
    let noise_seed = args.usize_or("noise-seed", 2025) as u64;
    let tokens: Vec<i32> = (0..window)
        .map(|i| ((i * 37 + 11) % frontier_cfg.vocab) as i32)
        .collect();
    println!(
        "\nmeasured analog frontier on {} ({} strategies x {} ADC caps x {} sigmas, {}-token window):",
        frontier_cfg.name,
        Strategy::all().len(),
        caps.len(),
        sigmas.len(),
        window
    );
    let front = explore_measured(
        &frontier_cfg,
        &params,
        model_seed,
        noise_seed,
        &caps,
        &sigmas,
        &tokens,
    );
    let mut ft = monarch_cim::util::table::Table::new([
        "strategy",
        "cap",
        "eff bits",
        "sigma",
        "quantized",
        "µs/token",
        "nJ/token",
        "agree",
        "max|dlogit|",
        "dppl",
    ]);
    for p in &front {
        ft.row([
            p.strategy.name().to_string(),
            p.adc_bits
                .map(|b| format!("{b}b"))
                .unwrap_or_else(|| "-".into()),
            p.effective_bits.to_string(),
            format!("{}", p.write_sigma),
            format!("{:.2}", p.quantized_frac),
            format!("{:.3}", p.token_latency_ns / 1e3),
            format!("{:.1}", p.energy_nj),
            format!("{:.3}", p.divergence.token_agreement),
            format!("{:.2e}", p.divergence.max_abs_logit_err),
            format!("{:+.3e}", p.divergence.ppl_delta),
        ]);
    }
    ft.print();

    // ideal-settings gate: points with no noise and no biting cap are
    // bit-identical to the exact path by construction, so any measured
    // divergence there is a bug — CI asserts via --gate-ideal
    let ideal_broken: Vec<_> = front
        .iter()
        .filter(|p| p.is_ideal() && !p.divergence.is_exact())
        .collect();
    for p in &ideal_broken {
        eprintln!(
            "FAIL: ideal frontier point diverged: {} cap {:?} sigma {}",
            p.strategy.name(),
            p.adc_bits,
            p.write_sigma
        );
    }

    let out = args.str_or("out", "BENCH_dse.json");
    let json = obj(vec![
        ("bench", js("dse_frontier")),
        ("model", js(frontier_cfg.name)),
        ("window_tokens", num(window as f64)),
        ("model_seed", num(model_seed as f64)),
        ("noise_seed", num(noise_seed as f64)),
        ("sigmas", arr(sigmas.iter().map(|&x| num(x)))),
        (
            "adc_caps",
            arr(caps
                .iter()
                .map(|c| c.map(|b| num(b as f64)).unwrap_or(Json::Null))),
        ),
        (
            "points",
            arr(front.iter().map(|p| {
                obj(vec![
                    ("strategy", js(p.strategy.name())),
                    (
                        "adc_bits",
                        p.adc_bits.map(|b| num(b as f64)).unwrap_or(Json::Null),
                    ),
                    ("effective_bits", num(p.effective_bits as f64)),
                    ("write_sigma", num(p.write_sigma)),
                    ("token_latency_ns", num(p.token_latency_ns)),
                    ("energy_nj_per_token", num(p.energy_nj)),
                    ("quantized_frac", num(p.quantized_frac)),
                    ("ideal", Json::Bool(p.is_ideal())),
                    ("exact", Json::Bool(p.divergence.is_exact())),
                    (
                        "first_divergence",
                        p.divergence
                            .first_divergence
                            .map(|i| num(i as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("token_agreement", num(p.divergence.token_agreement)),
                    ("max_abs_logit_err", num(p.divergence.max_abs_logit_err)),
                    ("rms_logit_err", num(p.divergence.rms_logit_err)),
                    ("ppl_delta", num(p.divergence.ppl_delta)),
                ])
            })),
        ),
    ]);
    if let Err(e) = std::fs::write(&out, json.to_pretty() + "\n") {
        eprintln!("failed to write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out} ({} frontier points)", front.len());
    if args.has("gate-ideal") && !ideal_broken.is_empty() {
        std::process::exit(1);
    }
}

fn cmd_e2e(args: &Args) {
    println!("== monarch-cim e2e summary ==");
    // 1) pipeline over all models/strategies
    for model in ModelConfig::paper_models() {
        for strategy in Strategy::all() {
            let r = run_pipeline(&PipelineConfig::new(model.clone(), strategy));
            println!(
                "  {:<12} {:<9} arrays {:>5}  util {:>5.1}%  lat {:>8.3} ms  en {:>8.2} mJ",
                model.name,
                strategy.name(),
                r.mapping.arrays,
                100.0 * r.mapping.utilization(),
                r.cost.latency_ms(),
                r.cost.energy_mj()
            );
        }
    }
    // 2) runtime round trip (defers to `examples/` for the full driver)
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(monarch_cim::runtime::default_artifacts_dir);
    match monarch_cim::runtime::Runtime::new(&dir) {
        Ok(rt) => println!(
            "runtime: platform={}, {} artifacts in {:?}",
            rt.platform(),
            rt.manifest().artifacts.len(),
            dir
        ),
        Err(e) => println!("runtime unavailable ({e}); run `make artifacts`"),
    }
    println!("for the full e2e driver see: cargo run --release --example bert_e2e");
}
