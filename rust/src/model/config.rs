//! Transformer model configurations for the paper's three benchmarks
//! (§IV): BERT-large (encoder-only, seq 512), BART-large
//! (encoder-decoder, seq 1024) and GPT-2-medium (decoder-only, seq 1024).
//!
//! Only architecture *shapes* matter for mapping/scheduling/energy; see
//! DESIGN.md §1 for the checkpoint substitution rationale.

/// High-level architecture family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    EncoderOnly,
    DecoderOnly,
    EncoderDecoder,
}

/// Static transformer configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub arch: Arch,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// Encoder layer count (0 for decoder-only).
    pub enc_layers: usize,
    /// Decoder layer count (0 for encoder-only).
    pub dec_layers: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl ModelConfig {
    /// BERT-large: 24 encoder layers, d=1024, 340M-class.
    pub fn bert_large() -> Self {
        Self {
            name: "bert-large",
            arch: Arch::EncoderOnly,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            enc_layers: 24,
            dec_layers: 0,
            seq: 512,
            vocab: 30522,
        }
    }

    /// BART-large: 12 encoder + 12 decoder layers, d=1024.
    pub fn bart_large() -> Self {
        Self {
            name: "bart-large",
            arch: Arch::EncoderDecoder,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            enc_layers: 12,
            dec_layers: 12,
            seq: 1024,
            vocab: 50265,
        }
    }

    /// GPT-2-medium: 24 decoder layers, d=1024.
    pub fn gpt2_medium() -> Self {
        Self {
            name: "gpt2-medium",
            arch: Arch::DecoderOnly,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            enc_layers: 0,
            dec_layers: 24,
            seq: 1024,
            vocab: 50257,
        }
    }

    /// The paper's evaluation set, in figure order.
    pub fn paper_models() -> Vec<ModelConfig> {
        vec![Self::bert_large(), Self::bart_large(), Self::gpt2_medium()]
    }

    /// Look up a model by CLI name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "bert" | "bert-large" => Some(Self::bert_large()),
            "bart" | "bart-large" => Some(Self::bart_large()),
            "gpt2" | "gpt2-medium" => Some(Self::gpt2_medium()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Tiny config matching the AOT `tiny_lm` artifact (tests/e2e).
    pub fn tiny() -> Self {
        Self {
            name: "tiny",
            arch: Arch::DecoderOnly,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            enc_layers: 0,
            dec_layers: 2,
            seq: 32,
            vocab: 256,
        }
    }

    pub fn total_layers(&self) -> usize {
        self.enc_layers + self.dec_layers
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Monarch block size for `d_model` tiles: `b = sqrt(d_model)`.
    pub fn monarch_b(&self) -> usize {
        let b = (self.d_model as f64).sqrt().round() as usize;
        assert_eq!(b * b, self.d_model, "d_model must be a perfect square");
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_models_shapes() {
        let bert = ModelConfig::bert_large();
        assert_eq!(bert.total_layers(), 24);
        assert_eq!(bert.seq, 512);
        assert_eq!(bert.monarch_b(), 32);

        let bart = ModelConfig::bart_large();
        assert_eq!(bart.total_layers(), 24);
        assert_eq!(bart.arch, Arch::EncoderDecoder);
        assert_eq!(bart.seq, 1024);

        let gpt = ModelConfig::gpt2_medium();
        assert_eq!(gpt.arch, Arch::DecoderOnly);
        assert_eq!(gpt.d_head(), 64);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelConfig::by_name("bert").unwrap().name, "bert-large");
        assert_eq!(ModelConfig::by_name("gpt2").unwrap().name, "gpt2-medium");
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn tiny_matches_artifact_metadata() {
        let t = ModelConfig::tiny();
        assert_eq!(t.d_model, 64);
        assert_eq!(t.monarch_b(), 8);
        assert_eq!(t.dec_layers, 2);
    }
}
