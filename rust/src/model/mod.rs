//! Transformer model descriptions: benchmark configurations (§IV), the
//! matmul op-graph with the paper's Para/NonPara split, and Fig. 2b
//! params/FLOPs accounting.

pub mod config;
pub mod flops;
pub mod graph;

pub use config::{Arch, ModelConfig};
pub use flops::{count_report, CountReport};
pub use graph::{build_graph, para_ops, MatmulOp, OpKind, Stage};
