//! Matmul op-graph extraction: every matrix multiplication a forward
//! pass executes, tagged Para (has trained weights — D2S candidates,
//! mapped into CIM arrays) or NonPara (activation-activation — stays
//! dense, runs on the MHA unit), exactly the split of paper Fig. 2b.

use super::config::{Arch, ModelConfig};

/// Whether a matmul has trained weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Parameterized: weight matrix is stationary in CIM arrays.
    Para,
    /// Non-parameterized: activation x activation (attention scores /
    /// attention-weighted values).
    NonPara,
}

/// Position of an op inside the network (for scheduling dependencies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Encoder,
    Decoder,
}

/// One matmul in the forward pass: `out = X (rows x cols_in) @ W^T`,
/// i.e. the weight is `rows_out x cols_in`; activations have `seq` rows.
#[derive(Clone, Debug)]
pub struct MatmulOp {
    /// Human-readable name, e.g. `enc3.wq`.
    pub name: String,
    pub stage: Stage,
    pub layer: usize,
    pub kind: OpKind,
    /// Weight rows (output features) for Para; left-operand rows for NonPara.
    pub rows: usize,
    /// Weight cols (input features) for Para; contraction dim for NonPara.
    pub cols: usize,
    /// Batch dimension: number of activation rows driven through the op
    /// (sequence length, or seq*heads for per-head NonPara ops).
    pub batch: usize,
}

impl MatmulOp {
    /// Multiply-add FLOPs (x2 for mul+add).
    pub fn flops(&self) -> u64 {
        2 * self.batch as u64 * self.rows as u64 * self.cols as u64
    }

    /// Weight parameter count (0 for NonPara).
    pub fn params(&self) -> u64 {
        match self.kind {
            OpKind::Para => self.rows as u64 * self.cols as u64,
            OpKind::NonPara => 0,
        }
    }
}

/// Extract all matmuls of one full-sequence forward pass.
pub fn build_graph(cfg: &ModelConfig) -> Vec<MatmulOp> {
    let mut ops = Vec::new();
    let d = cfg.d_model;
    let s = cfg.seq;
    let h = cfg.n_heads;
    let dh = cfg.d_head();

    let push_attention =
        |ops: &mut Vec<MatmulOp>, stage: Stage, layer: usize, tag: &str, kv_len: usize| {
            for w in ["wq", "wk", "wv"] {
                ops.push(MatmulOp {
                    name: format!("{tag}{layer}.{w}"),
                    stage,
                    layer,
                    kind: OpKind::Para,
                    rows: d,
                    cols: d,
                    batch: s,
                });
            }
            // scores: per head (s x dh) @ (dh x kv_len)
            ops.push(MatmulOp {
                name: format!("{tag}{layer}.qk"),
                stage,
                layer,
                kind: OpKind::NonPara,
                rows: s,
                cols: dh,
                batch: h * kv_len,
            });
            // context: per head (s x kv_len) @ (kv_len x dh)
            ops.push(MatmulOp {
                name: format!("{tag}{layer}.av"),
                stage,
                layer,
                kind: OpKind::NonPara,
                rows: s,
                cols: kv_len,
                batch: h * dh,
            });
            ops.push(MatmulOp {
                name: format!("{tag}{layer}.wo"),
                stage,
                layer,
                kind: OpKind::Para,
                rows: d,
                cols: d,
                batch: s,
            });
        };

    let push_ffn = |ops: &mut Vec<MatmulOp>, stage: Stage, layer: usize, tag: &str| {
        ops.push(MatmulOp {
            name: format!("{tag}{layer}.ffn1"),
            stage,
            layer,
            kind: OpKind::Para,
            rows: cfg.d_ff,
            cols: d,
            batch: s,
        });
        ops.push(MatmulOp {
            name: format!("{tag}{layer}.ffn2"),
            stage,
            layer,
            kind: OpKind::Para,
            rows: d,
            cols: cfg.d_ff,
            batch: s,
        });
    };

    for l in 0..cfg.enc_layers {
        push_attention(&mut ops, Stage::Encoder, l, "enc", s);
        push_ffn(&mut ops, Stage::Encoder, l, "enc");
    }
    for l in 0..cfg.dec_layers {
        push_attention(&mut ops, Stage::Decoder, l, "dec", s);
        if cfg.arch == Arch::EncoderDecoder {
            // cross-attention over encoder outputs
            push_attention(&mut ops, Stage::Decoder, l, "xdec", s);
        }
        push_ffn(&mut ops, Stage::Decoder, l, "dec");
    }
    ops
}

/// Only the parameterized ops (the CIM-mapped weight set).
pub fn para_ops(cfg: &ModelConfig) -> Vec<MatmulOp> {
    build_graph(cfg)
        .into_iter()
        .filter(|o| o.kind == OpKind::Para)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_counts() {
        let cfg = ModelConfig::bert_large();
        let ops = build_graph(&cfg);
        // per layer: 4 para attention + 2 para ffn + 2 nonpara
        assert_eq!(ops.len(), 24 * 8);
        let para = ops.iter().filter(|o| o.kind == OpKind::Para).count();
        assert_eq!(para, 24 * 6);
    }

    #[test]
    fn bart_has_cross_attention() {
        let cfg = ModelConfig::bart_large();
        let ops = build_graph(&cfg);
        // enc: 12*8; dec: 12*(6 self + 6 cross + 2 ffn... self=4p+2n, cross=4p+2n, ffn=2p)
        assert_eq!(ops.len(), 12 * 8 + 12 * 14);
        assert!(ops.iter().any(|o| o.name.starts_with("xdec")));
    }

    #[test]
    fn para_params_match_closed_form() {
        let cfg = ModelConfig::bert_large();
        let total: u64 = para_ops(&cfg).iter().map(|o| o.params()).sum();
        // per layer 4 d^2 + 2 * d * d_ff
        let want = 24 * (4 * 1024u64 * 1024 + 2 * 1024 * 4096);
        assert_eq!(total, want);
    }

    #[test]
    fn nonpara_flops_match_closed_form() {
        let cfg = ModelConfig::bert_large();
        let nonpara: u64 = build_graph(&cfg)
            .iter()
            .filter(|o| o.kind == OpKind::NonPara)
            .map(|o| o.flops())
            .sum();
        // per layer 4 * s^2 * d
        let want = 24 * 4 * 512u64 * 512 * 1024;
        assert_eq!(nonpara, want);
    }

    #[test]
    fn names_unique() {
        let cfg = ModelConfig::bart_large();
        let ops = build_graph(&cfg);
        let mut names: Vec<&str> = ops.iter().map(|o| o.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ops.len());
    }
}
