//! Parameter-count and FLOP accounting, dense vs Monarch — reproduces
//! paper Fig. 2b (BERT-large, 512-token input: ~8x params, ~5.7x FLOPs,
//! Para-Matmuls > 80% of FLOPs).
//!
//! Monarch accounting per square `n x n` tile (`b = sqrt(n)`):
//! params `2 b^3 = 2 n sqrt(n)`; per-activation-row FLOPs `4 n b`
//! (two block-diagonal stages of `2 n b` each; permutations are free).
//! Rectangular weights are tiled into `d x d` squares
//! (`monarch::rect`), so an `r x c` weight has `ceil(r/d)*ceil(c/d)`
//! tiles.

use super::config::ModelConfig;
use super::graph::{build_graph, MatmulOp, OpKind};

/// Fig. 2b-style accounting summary.
#[derive(Clone, Debug)]
pub struct CountReport {
    pub model: String,
    pub seq: usize,
    // parameters
    pub dense_para_params: u64,
    pub monarch_para_params: u64,
    pub other_params: u64,
    // FLOPs for one full-sequence forward pass
    pub dense_para_flops: u64,
    pub monarch_para_flops: u64,
    pub nonpara_flops: u64,
}

impl CountReport {
    /// Params reduction over the D2S-transformed (Para) weights.
    pub fn para_param_reduction(&self) -> f64 {
        self.dense_para_params as f64 / self.monarch_para_params as f64
    }

    /// Whole-model params reduction (embeddings etc. untransformed).
    pub fn model_param_reduction(&self) -> f64 {
        (self.dense_para_params + self.other_params) as f64
            / (self.monarch_para_params + self.other_params) as f64
    }

    /// Whole-forward FLOPs reduction (NonPara untransformed).
    pub fn flops_reduction(&self) -> f64 {
        (self.dense_para_flops + self.nonpara_flops) as f64
            / (self.monarch_para_flops + self.nonpara_flops) as f64
    }

    /// Fraction of dense FLOPs that are parameterized (paper: >80%).
    pub fn para_flops_fraction(&self) -> f64 {
        self.dense_para_flops as f64
            / (self.dense_para_flops + self.nonpara_flops) as f64
    }
}

/// Monarch parameter count for one Para matmul (square-tile partition).
pub fn monarch_params_of(op: &MatmulOp, d: usize) -> u64 {
    debug_assert_eq!(op.kind, OpKind::Para);
    let b = (d as f64).sqrt().round() as usize;
    let tiles = op.rows.div_ceil(d) as u64 * op.cols.div_ceil(d) as u64;
    tiles * 2 * (b * b * b) as u64
}

/// Monarch FLOPs for one Para matmul over its activation batch.
pub fn monarch_flops_of(op: &MatmulOp, d: usize) -> u64 {
    debug_assert_eq!(op.kind, OpKind::Para);
    let b = (d as f64).sqrt().round() as usize;
    let tiles = op.rows.div_ceil(d) as u64 * op.cols.div_ceil(d) as u64;
    tiles * op.batch as u64 * (4 * d * b) as u64
}

/// Embedding/positional/LayerNorm parameters left dense by the paper.
pub fn untransformed_params(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let emb = cfg.vocab as u64 * d + cfg.seq as u64 * d;
    // LayerNorm scale+bias: 2 per attention/ffn sub-block + final
    let ln_per_layer = 2 * 2 * d;
    emb + cfg.total_layers() as u64 * ln_per_layer + 2 * d
}

/// Build the Fig. 2b accounting for a model.
pub fn count_report(cfg: &ModelConfig) -> CountReport {
    let d = cfg.d_model;
    let ops = build_graph(cfg);
    let mut r = CountReport {
        model: cfg.name.to_string(),
        seq: cfg.seq,
        dense_para_params: 0,
        monarch_para_params: 0,
        other_params: untransformed_params(cfg),
        dense_para_flops: 0,
        monarch_para_flops: 0,
        nonpara_flops: 0,
    };
    for op in &ops {
        match op.kind {
            OpKind::Para => {
                r.dense_para_params += op.params();
                r.monarch_para_params += monarch_params_of(op, d);
                r.dense_para_flops += op.flops();
                r.monarch_para_flops += monarch_flops_of(op, d);
            }
            OpKind::NonPara => {
                r.nonpara_flops += op.flops();
            }
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_para_reduction_is_16x() {
        // d=1024, b=32: dense d^2 = 1M, monarch 2b^3 = 64K -> exactly 16x
        // per square tile, and FFN tiles reduce by the same factor.
        let r = count_report(&ModelConfig::bert_large());
        assert!((r.para_param_reduction() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn bert_fig2b_shape() {
        let r = count_report(&ModelConfig::bert_large());
        // Para-matmuls dominate FLOPs (paper: >80%)
        assert!(
            r.para_flops_fraction() > 0.8,
            "para fraction {}",
            r.para_flops_fraction()
        );
        // Model-level params reduction in the 4x..10x band (paper: 8x)
        let pr = r.model_param_reduction();
        assert!(pr > 4.0 && pr < 10.0, "param reduction {pr}");
        // FLOPs reduction in the 4x..8x band (paper: 5.7x)
        let fr = r.flops_reduction();
        assert!(fr > 4.0 && fr < 8.0, "flops reduction {fr}");
    }

    #[test]
    fn monarch_ffn_tiles_counted() {
        let cfg = ModelConfig::bert_large();
        let op = MatmulOp {
            name: "ffn1".into(),
            stage: super::super::graph::Stage::Encoder,
            layer: 0,
            kind: OpKind::Para,
            rows: cfg.d_ff,
            cols: cfg.d_model,
            batch: cfg.seq,
        };
        // 4 tiles of 1024x1024
        assert_eq!(monarch_params_of(&op, 1024), 4 * 2 * 32768);
    }

    #[test]
    fn all_paper_models_have_reports() {
        for cfg in ModelConfig::paper_models() {
            let r = count_report(&cfg);
            assert!(r.dense_para_params > 0);
            assert!(r.monarch_para_params < r.dense_para_params);
            assert!(r.flops_reduction() > 1.0);
        }
    }

    #[test]
    fn gpt2_param_scale_sane() {
        // GPT-2 medium is a ~350M model; our para+other accounting should
        // land in the 300-420M band.
        let r = count_report(&ModelConfig::gpt2_medium());
        let total = r.dense_para_params + r.other_params;
        assert!(
            (300_000_000..420_000_000).contains(&total),
            "total {total}"
        );
    }
}
