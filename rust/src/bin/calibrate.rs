//! Internal calibration probe: prints the Fig. 7/8 ratios the timing
//! model currently produces (used during §Perf and model tuning).

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::util::stats::geomean;

fn main() {
    let params = CimParams::default();
    let mut sp = Vec::new();
    let mut de = Vec::new();
    let mut spe = Vec::new();
    let mut dee = Vec::new();
    for cfg in ModelConfig::paper_models() {
        let lin = cost_report(&cfg, &params, Strategy::Linear);
        let s = cost_report(&cfg, &params, Strategy::SparseMap);
        let d = cost_report(&cfg, &params, Strategy::DenseMap);
        println!(
            "{:<12} lat(ms): lin {:.3} sp {:.3} de {:.3} | en(mJ): lin {:.3} sp {:.3} de {:.3}",
            cfg.name,
            lin.latency_ms(),
            s.latency_ms(),
            d.latency_ms(),
            lin.energy_mj(),
            s.energy_mj(),
            d.energy_mj()
        );
        println!(
            "  breakdown lin/token: analog {:.1} adc {:.1} comm {:.1} dpu {:.1}",
            lin.per_token.latency.analog_ns,
            lin.per_token.latency.adc_ns,
            lin.per_token.latency.comm_ns,
            lin.per_token.latency.dpu_ns
        );
        println!(
            "  breakdown  de/token: analog {:.1} adc {:.1} comm {:.1} dpu {:.1}",
            d.per_token.latency.analog_ns,
            d.per_token.latency.adc_ns,
            d.per_token.latency.comm_ns,
            d.per_token.latency.dpu_ns
        );
        for (tag, r) in [("lin", &lin), ("sp ", &s), ("de ", &d)] {
            println!(
                "  energy {tag}/token: analog {:.0} adc {:.0} comm {:.0} dpu {:.0}",
                r.per_token.energy.analog_nj,
                r.per_token.energy.adc_nj,
                r.per_token.energy.comm_nj,
                r.per_token.energy.dpu_nj
            );
        }
        sp.push(lin.latency_ms() / s.latency_ms());
        de.push(lin.latency_ms() / d.latency_ms());
        spe.push(lin.energy_mj() / s.energy_mj());
        dee.push(lin.energy_mj() / d.energy_mj());
    }
    println!(
        "geomean latency speedups: sparse {:.3} (paper 1.59), dense {:.3} (paper 1.73)",
        geomean(&sp),
        geomean(&de)
    );
    println!(
        "geomean energy gains:     sparse {:.3} (paper 1.61), dense {:.3} (paper 1.74)",
        geomean(&spe),
        geomean(&dee)
    );
    println!("\nFig8 (BERT latency ms):");
    let cfg = ModelConfig::bert_large();
    for adcs in [1usize, 4, 8, 16, 32] {
        let p = CimParams::default().with_adcs_per_array(adcs);
        let l = cost_report(&cfg, &p, Strategy::Linear).latency_ms();
        let s = cost_report(&cfg, &p, Strategy::SparseMap).latency_ms();
        let d = cost_report(&cfg, &p, Strategy::DenseMap).latency_ms();
        println!(
            "  {adcs:>2} ADCs: lin {l:.3} sp {s:.3} de {d:.3}  (de/lin {:.2}, sp/de {:.2}, lin/sp {:.2})",
            l / d,
            d / s,
            l / s,
        );
    }
}
