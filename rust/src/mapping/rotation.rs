//! Rotation handling for DenseMap (§III-B2a): a block-diagonal packed at
//! diagonal index `i` of an array produces outputs cyclically rotated by
//! `i` block positions. Pairing the L-stage lane at index `i_L` with the
//! R-stage lane at `i_R = -i_L (mod lanes)` cancels the rotations, so no
//! explicit rotation correction is needed between stages.
//!
//! Special case: indices `0` and `lanes/2` are self-inverse under the
//! modulo, so an L/R pair at such an index would need the *same*
//! diagonal twice in one array — impossible. These lanes are distributed
//! across different arrays (§III-B2a "must be distributed across
//! different Monarch matrices").

/// Output block-rotation produced by a lane at diagonal index `i`.
pub fn rotation_of(diag: usize, lanes: usize) -> usize {
    diag % lanes
}

/// The cancelling partner index: `i_R = -i_L mod lanes`.
pub fn pair_index(i_l: usize, lanes: usize) -> usize {
    (lanes - (i_l % lanes)) % lanes
}

/// Self-inverse diagonal indices (cannot pair inside one array).
pub fn is_self_inverse(i: usize, lanes: usize) -> bool {
    pair_index(i, lanes) == i
}

/// Net rotation after composing an L lane at `i_l` with an R lane at
/// `i_r` (zero when properly paired).
pub fn net_rotation(i_l: usize, i_r: usize, lanes: usize) -> usize {
    (i_l + i_r) % lanes
}

/// Cyclically rotate a vector left by `rot` block positions of size `b`
/// (functional model of the lane output alignment).
pub fn rotate_blocks_left(x: &[f32], b: usize, rot: usize) -> Vec<f32> {
    assert_eq!(x.len() % b, 0);
    let nblocks = x.len() / b;
    let rot = rot % nblocks.max(1);
    let mut out = vec![0.0f32; x.len()];
    for blk in 0..nblocks {
        let src = (blk + rot) % nblocks;
        out[blk * b..(blk + 1) * b].copy_from_slice(&x[src * b..(src + 1) * b]);
    }
    out
}

/// Plan the lane-diagonal assignment for a sequence of (L, R) lane pairs
/// being packed into arrays with `lanes` diagonals each.
///
/// Returns `(diag_l, diag_r, same_array)` per pair: non-self-inverse
/// pairs co-reside (`same_array = true`) at complementary indices;
/// self-inverse pairs are split across arrays at the same index.
pub struct PairPlanner {
    lanes: usize,
    /// Next non-self-inverse index to hand out (cycles through 1..lanes/2).
    cursor: usize,
}

impl PairPlanner {
    pub fn new(lanes: usize) -> Self {
        assert!(lanes >= 1);
        Self { lanes, cursor: 0 }
    }

    /// Indices that pair with a distinct partner.
    fn pairable(&self) -> Vec<usize> {
        (1..self.lanes)
            .filter(|&i| !is_self_inverse(i, self.lanes))
            .collect()
    }

    /// Assign the next (L, R) pair.
    pub fn next_pair(&mut self) -> (usize, usize, bool) {
        let pairable = self.pairable();
        if pairable.is_empty() {
            // lanes <= 2: only self-inverse diagonals exist
            let i = self.cursor % self.lanes.max(1);
            self.cursor += 1;
            return (i, i, false);
        }
        // Use each unordered pair {i, lanes - i} once per array fill.
        let half: Vec<usize> = pairable
            .iter()
            .copied()
            .filter(|&i| i < pair_index(i, self.lanes) || self.lanes == 2)
            .collect();
        let i = half[self.cursor % half.len()];
        self.cursor += 1;
        (i, pair_index(i, self.lanes), true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn pairing_cancels_rotation() {
        forall("i_R = -i_L cancels", 50, |g| {
            let lanes = g.usize(1, 16);
            let i_l = g.usize(0, lanes - 1);
            let i_r = pair_index(i_l, lanes);
            assert_eq!(net_rotation(i_l, i_r, lanes), 0);
        });
    }

    #[test]
    fn self_inverse_indices() {
        assert!(is_self_inverse(0, 8));
        assert!(is_self_inverse(4, 8));
        for i in [1, 2, 3, 5, 6, 7] {
            assert!(!is_self_inverse(i, 8), "index {i}");
        }
        // odd lane count: only 0 is self-inverse
        assert!(is_self_inverse(0, 7));
        for i in 1..7 {
            assert!(!is_self_inverse(i, 7), "index {i}");
        }
    }

    #[test]
    fn rotate_blocks_roundtrip() {
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let r = rotate_blocks_left(&x, 3, 1);
        assert_eq!(&r[0..3], &[3.0, 4.0, 5.0]);
        // rotating by lanes is identity
        assert_eq!(rotate_blocks_left(&x, 3, 4), x);
        // rot then counter-rot restores
        let rr = rotate_blocks_left(&r, 3, 3); // 1 + 3 = 4 ≡ 0 (mod 4)
        assert_eq!(rr, x);
    }

    #[test]
    fn planner_pairs_are_complementary() {
        let mut pl = PairPlanner::new(8);
        for _ in 0..10 {
            let (l, r, same) = pl.next_pair();
            assert_eq!(net_rotation(l, r, 8), 0);
            if same {
                assert_ne!(l, r, "co-resident pair must use distinct diagonals");
            }
        }
    }

    #[test]
    fn planner_handles_tiny_lane_counts() {
        let mut pl = PairPlanner::new(1);
        let (l, r, same) = pl.next_pair();
        assert_eq!((l, r, same), (0, 0, false));
        let mut pl2 = PairPlanner::new(2);
        let (l, r, same) = pl2.next_pair();
        assert_eq!(net_rotation(l, r, 2), 0);
        assert!(!same); // 0 and 1 are both self-inverse mod 2
    }
}
