//! CIM mapping strategies (paper §III-B): placing weight structures onto
//! m x m crossbar arrays.
//!
//! * [`linear`] — **Linear** baseline: dense pre-trained weights tiled
//!   directly onto arrays (100% utilization, most arrays).
//! * [`sparse`] — **SparseMap** (§III-B1, latency-optimized): Monarch
//!   block-diagonals along each array's diagonal, zero-padding the rest;
//!   utilization b/m, all blocks compute in parallel.
//! * [`dense`] — **DenseMap** (§III-B2, capacity-optimized): up to m/b
//!   block-diagonal *lanes* per array at distinct diagonal indices, with
//!   rotation-cancelling lane pairing ([`rotation`], `i_R = -i_L mod
//!   lanes`) and permutation folding; utilization approaches 100%.
//!
//! The output [`ModelMapping`] carries both the figure-6 statistics
//! (array counts, utilization) and the execution geometry the scheduler
//! needs (per-op array spans, activation masks, co-location).

pub mod constrained;
pub mod dense;
pub mod linear;
pub mod rotation;
pub mod sparse;
pub mod stats;

use crate::cim::CimParams;
use crate::model::{MatmulOp, ModelConfig};

/// Mapping strategy selector (the paper's three configurations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    Linear,
    SparseMap,
    DenseMap,
}

impl Strategy {
    pub fn all() -> [Strategy; 3] {
        [Strategy::Linear, Strategy::SparseMap, Strategy::DenseMap]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Linear => "Linear",
            Strategy::SparseMap => "SparseMap",
            Strategy::DenseMap => "DenseMap",
        }
    }

    pub fn by_name(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(Strategy::Linear),
            "sparse" | "sparsemap" => Some(Strategy::SparseMap),
            "dense" | "densemap" => Some(Strategy::DenseMap),
            _ => None,
        }
    }
}

/// Which Monarch factor a placement belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Factor {
    /// Dense weight tile (Linear mapping only).
    Dense,
    /// Left block-diagonal factor `L`.
    Left,
    /// Right block-diagonal factor `R`.
    Right,
}

/// A contiguous group of blocks placed into one array.
///
/// Granularity: for Linear one placement = one m x m dense tile; for
/// SparseMap/DenseMap one placement = one *lane* (a run of up to m/b
/// blocks at diagonal index `diag`).
#[derive(Clone, Debug)]
pub struct Placement {
    /// Index into the mapped op list.
    pub op: usize,
    /// d x d tile index within the op (rectangular partition).
    pub tile: usize,
    pub factor: Factor,
    /// Lane ordinal within the factor (0.. ceil(b / (m/b))).
    pub lane_of_factor: usize,
    /// Physical array id.
    pub array: usize,
    /// Diagonal index inside the array (0 for Linear/SparseMap).
    pub diag: usize,
    /// Blocks in this placement.
    pub blocks: usize,
    /// Block edge (b for Monarch lanes, m for Linear tiles).
    pub block_dim: usize,
    /// Valid (non-padded) cells this placement stores.
    pub cells: usize,
}

/// Execution geometry of one mapped parameterized op, consumed by the
/// scheduler.
#[derive(Clone, Debug)]
pub struct MappedOp {
    pub name: String,
    pub layer: usize,
    /// Weight rows (output features) of the original matmul.
    pub rows: usize,
    /// Weight cols (input features) of the original matmul.
    pub cols: usize,
    /// d x d tiles (rectangular partition of the weight).
    pub tiles: usize,
    /// Arrays whose placements belong to this op.
    pub arrays: Vec<usize>,
    /// Arrays active in parallel per Monarch stage (or per dense pass).
    pub stage_arrays: usize,
    /// Sequential Monarch stages (2) or 1 for Linear.
    pub stages: usize,
    /// ADC conversions per array per token per stage.
    pub convs_per_array: usize,
    /// Active rows per column during a pass.
    pub active_rows: usize,
    /// Partial-sum additions per output element (Linear col partitions).
    pub partial_adds: usize,
    /// Sequential analog phases per token per stage (DenseMap lanes of
    /// the same op co-resident in one array).
    pub analog_phases: usize,
}

/// Full mapping of a model's parameterized ops.
#[derive(Clone, Debug)]
pub struct ModelMapping {
    pub strategy: Strategy,
    pub model: String,
    /// Array dimension m.
    pub m: usize,
    /// Monarch block size b (0 for Linear).
    pub b: usize,
    /// Total arrays allocated.
    pub arrays: usize,
    pub placements: Vec<Placement>,
    pub ops: Vec<MappedOp>,
}

impl ModelMapping {
    /// Valid cells stored across all placements.
    pub fn used_cells(&self) -> usize {
        self.placements.iter().map(|p| p.cells).sum()
    }

    /// Array-wise utilization: valid cells / total allocated capacity.
    pub fn utilization(&self) -> f64 {
        if self.arrays == 0 {
            return 0.0;
        }
        self.used_cells() as f64 / (self.arrays * self.m * self.m) as f64
    }
}

/// Map a model's parameterized matmuls with the chosen strategy.
pub fn map_model(
    cfg: &ModelConfig,
    params: &CimParams,
    strategy: Strategy,
) -> ModelMapping {
    let ops = crate::model::para_ops(cfg);
    map_ops(cfg, &ops, params, strategy)
}

/// Map an explicit op list (used by tests and the pipeline).
pub fn map_ops(
    cfg: &ModelConfig,
    ops: &[MatmulOp],
    params: &CimParams,
    strategy: Strategy,
) -> ModelMapping {
    match strategy {
        Strategy::Linear => linear::map(cfg, ops, params),
        Strategy::SparseMap => sparse::map(cfg, ops, params),
        Strategy::DenseMap => dense::map(cfg, ops, params),
    }
}

/// Number of d x d square tiles of a rectangular weight.
pub(crate) fn tiles_of(op: &MatmulOp, d: usize) -> usize {
    op.rows.div_ceil(d) * op.cols.div_ceil(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::by_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::by_name("densemap"), Some(Strategy::DenseMap));
        assert!(Strategy::by_name("x").is_none());
    }
}
