//! Array-budget-constrained execution (paper §III-B1): "for systems with
//! a limited number of CIM arrays, this mapping requires rewriting array
//! data (swapping it with new data) dynamically during execution, which
//! incurs significant overhead, especially in NVM-based CIM systems."
//!
//! This module quantifies that overhead — the motivation for DenseMap on
//! resource-constrained devices. Given a physical array budget `A` and a
//! mapping needing `N` arrays, the weight-stationary dataflow breaks for
//! `N > A`: arrays must be reprogrammed mid-inference. Layers are visited
//! cyclically (token after token), so an LRU residency policy thrashes:
//! every non-resident array is rewritten once per token pass.

use super::{ModelMapping, Strategy};
use crate::cim::CimParams;

/// PCM write-cost model (typical NVM programming costs; Table I does not
/// include writes because the paper's main flow is weight-stationary).
#[derive(Clone, Debug)]
pub struct WriteCosts {
    /// Time to (re)program one full m x m array, ns. PCM iterative
    /// program-and-verify is ~1 µs/row-group; 256 rows ≈ 100 µs.
    pub t_array_write_ns: f64,
    /// Energy to reprogram one array, nJ (~pJ/cell * 64k cells).
    pub e_array_write_nj: f64,
}

impl Default for WriteCosts {
    fn default() -> Self {
        Self {
            t_array_write_ns: 100_000.0,
            e_array_write_nj: 65_536.0 * 0.05, // 50 pJ / cell
        }
    }
}

/// Swap-overhead report for one (mapping, budget) pair.
#[derive(Clone, Debug)]
pub struct SwapReport {
    pub strategy: Strategy,
    pub arrays_needed: usize,
    pub array_budget: usize,
    /// Arrays rewritten per token pass (0 when the model fits).
    pub swaps_per_token: usize,
    /// Added latency per token from reprogramming, ns.
    pub swap_latency_ns: f64,
    /// Added energy per token from reprogramming, nJ.
    pub swap_energy_nj: f64,
    pub fits: bool,
}

/// Evaluate the §III-B1 swap overhead under an array budget.
///
/// Residency model: LRU over the cyclic layer schedule. When `N > A`,
/// the reuse distance of every array equals `N`, so *every* access to a
/// non-pinned array misses: `N - A` rewrites per token pass.
pub fn swap_overhead(
    mapping: &ModelMapping,
    budget: usize,
    costs: &WriteCosts,
) -> SwapReport {
    let n = mapping.arrays;
    let swaps = n.saturating_sub(budget);
    SwapReport {
        strategy: mapping.strategy,
        arrays_needed: n,
        array_budget: budget,
        swaps_per_token: swaps,
        swap_latency_ns: swaps as f64 * costs.t_array_write_ns,
        swap_energy_nj: swaps as f64 * costs.e_array_write_nj,
        fits: swaps == 0,
    }
}

/// Effective per-token latency including swap overhead (ns).
pub fn constrained_token_latency_ns(
    mapping: &ModelMapping,
    cfg: &crate::model::ModelConfig,
    params: &CimParams,
    budget: usize,
    costs: &WriteCosts,
) -> f64 {
    let base = crate::scheduler::timing::per_token_cost(cfg, mapping, params)
        .latency
        .critical_ns();
    base + swap_overhead(mapping, budget, costs).swap_latency_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::map_model;
    use crate::model::ModelConfig;

    #[test]
    fn fitting_mapping_has_zero_overhead() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let de = map_model(&cfg, &params, Strategy::DenseMap);
        let r = swap_overhead(&de, 1000, &WriteCosts::default());
        assert!(r.fits);
        assert_eq!(r.swaps_per_token, 0);
        assert_eq!(r.swap_latency_ns, 0.0);
    }

    #[test]
    fn linear_thrashes_under_tight_budget() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let lin = map_model(&cfg, &params, Strategy::Linear);
        let r = swap_overhead(&lin, 1000, &WriteCosts::default());
        assert!(!r.fits);
        assert_eq!(r.swaps_per_token, lin.arrays - 1000);
        assert!(r.swap_latency_ns > 1e8); // >100 ms of writes per token
    }

    #[test]
    fn densemap_wins_big_when_constrained() {
        // The paper's motivation: on a budget where DenseMap fits and
        // Linear does not, the effective gap explodes far past 1.73x.
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let costs = WriteCosts::default();
        let budget = 512;
        let lin = map_model(&cfg, &params, Strategy::Linear);
        let de = map_model(&cfg, &params, Strategy::DenseMap);
        let t_lin = constrained_token_latency_ns(&lin, &cfg, &params, budget, &costs);
        let t_de = constrained_token_latency_ns(&de, &cfg, &params, budget, &costs);
        assert!(swap_overhead(&de, budget, &costs).fits);
        assert!(
            t_lin / t_de > 100.0,
            "constrained speedup only {:.1}x",
            t_lin / t_de
        );
    }

    #[test]
    fn overhead_monotone_in_budget() {
        let cfg = ModelConfig::gpt2_medium();
        let params = CimParams::default();
        let lin = map_model(&cfg, &params, Strategy::Linear);
        let costs = WriteCosts::default();
        let mut prev = f64::INFINITY;
        for budget in [100usize, 500, 1000, 2000, 5000] {
            let r = swap_overhead(&lin, budget, &costs);
            assert!(r.swap_latency_ns <= prev);
            prev = r.swap_latency_ns;
        }
    }
}
