//! Mapping statistics — the quantities of paper Fig. 6: CIM array counts
//! (6a) and array-wise utilization (6b) per model and strategy.

use super::{map_model, ModelMapping, Strategy};
use crate::cim::CimParams;
use crate::model::ModelConfig;

/// One Fig. 6 row.
#[derive(Clone, Debug)]
pub struct MappingStats {
    pub model: String,
    pub strategy: Strategy,
    pub arrays: usize,
    /// Valid cells / allocated capacity, in [0, 1].
    pub utilization: f64,
    /// Stored weight memory in MiB (f32 cells).
    pub memory_mib: f64,
}

impl MappingStats {
    pub fn from_mapping(mm: &ModelMapping) -> Self {
        Self {
            model: mm.model.clone(),
            strategy: mm.strategy,
            arrays: mm.arrays,
            utilization: mm.utilization(),
            memory_mib: (mm.used_cells() * 4) as f64 / (1024.0 * 1024.0),
        }
    }
}

/// Compute Fig. 6 for all paper models and strategies.
pub fn fig6_stats(params: &CimParams) -> Vec<MappingStats> {
    let mut out = Vec::new();
    for cfg in ModelConfig::paper_models() {
        for s in Strategy::all() {
            let mm = map_model(&cfg, params, s);
            out.push(MappingStats::from_mapping(&mm));
        }
    }
    out
}

/// Average reduction in array count of `a` vs `b` across models.
pub fn mean_array_reduction(stats: &[MappingStats], a: Strategy, b: Strategy) -> f64 {
    let mut ratios = Vec::new();
    let models: std::collections::BTreeSet<&str> =
        stats.iter().map(|s| s.model.as_str()).collect();
    for m in models {
        let fa = stats
            .iter()
            .find(|s| s.model == m && s.strategy == a)
            .expect("missing stats");
        let fb = stats
            .iter()
            .find(|s| s.model == m && s.strategy == b)
            .expect("missing stats");
        ratios.push(1.0 - fa.arrays as f64 / fb.arrays as f64);
    }
    crate::util::stats::mean(&ratios)
}

/// Average utilization of a strategy across models.
pub fn mean_utilization(stats: &[MappingStats], s: Strategy) -> f64 {
    let xs: Vec<f64> = stats
        .iter()
        .filter(|x| x.strategy == s)
        .map(|x| x.utilization)
        .collect();
    crate::util::stats::mean(&xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape_holds() {
        let params = CimParams::default();
        let stats = fig6_stats(&params);
        assert_eq!(stats.len(), 9);

        // Fig. 6a: SparseMap ~50% fewer arrays than Linear
        let sp_red = mean_array_reduction(&stats, Strategy::SparseMap, Strategy::Linear);
        assert!((0.4..0.6).contains(&sp_red), "sparse reduction {sp_red}");

        // DenseMap ~87% fewer than Linear, >73% fewer than SparseMap
        let de_red = mean_array_reduction(&stats, Strategy::DenseMap, Strategy::Linear);
        assert!(de_red > 0.8, "dense reduction {de_red}");
        let de_vs_sp = mean_array_reduction(&stats, Strategy::DenseMap, Strategy::SparseMap);
        assert!(de_vs_sp > 0.7, "dense vs sparse {de_vs_sp}");

        // Fig. 6b: Linear 100%, SparseMap ~20%, DenseMap ~79%
        assert!((mean_utilization(&stats, Strategy::Linear) - 1.0).abs() < 1e-9);
        let sp_util = mean_utilization(&stats, Strategy::SparseMap);
        assert!((0.1..0.3).contains(&sp_util), "sparse util {sp_util}");
        let de_util = mean_utilization(&stats, Strategy::DenseMap);
        assert!(de_util > 0.7, "dense util {de_util}");
        assert!(de_util > 2.5 * sp_util, "dense/sparse util ratio"); // ~3x (§IV-A)
    }

    #[test]
    fn memory_footprint_reduction() {
        // DenseMap stores 16x fewer weight cells than Linear (b=32),
        // > 4x memory footprint reduction claim of the abstract.
        let params = CimParams::default();
        let stats = fig6_stats(&params);
        let lin: f64 = stats
            .iter()
            .filter(|s| s.strategy == Strategy::Linear)
            .map(|s| s.memory_mib)
            .sum();
        let de: f64 = stats
            .iter()
            .filter(|s| s.strategy == Strategy::DenseMap)
            .map(|s| s.memory_mib)
            .sum();
        assert!(lin / de > 4.0, "memory reduction {}", lin / de);
    }
}
