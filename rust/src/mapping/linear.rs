//! Linear baseline mapping (§IV, "Linear"): the dense pre-trained weight
//! matrices are tiled directly onto m x m arrays. Utilization is 100%
//! for dimension multiples of m (the paper's models all are); every
//! column partition produces partial sums that are shift-added.

use super::{Factor, MappedOp, ModelMapping, Placement, Strategy};
use crate::cim::CimParams;
use crate::model::{MatmulOp, ModelConfig};

pub fn map(cfg: &ModelConfig, ops: &[MatmulOp], params: &CimParams) -> ModelMapping {
    let m = params.array_dim;
    let mut placements = Vec::new();
    let mut mapped_ops = Vec::new();
    let mut next_array = 0usize;

    for (oi, op) in ops.iter().enumerate() {
        let row_parts = op.rows.div_ceil(m);
        let col_parts = op.cols.div_ceil(m);
        let mut arrays = Vec::with_capacity(row_parts * col_parts);
        for rp in 0..row_parts {
            for cp in 0..col_parts {
                let rows_here = m.min(op.rows - rp * m);
                let cols_here = m.min(op.cols - cp * m);
                placements.push(Placement {
                    op: oi,
                    tile: rp * col_parts + cp,
                    factor: Factor::Dense,
                    lane_of_factor: 0,
                    array: next_array,
                    diag: 0,
                    blocks: 1,
                    block_dim: m,
                    cells: rows_here * cols_here,
                });
                arrays.push(next_array);
                next_array += 1;
            }
        }
        // Per token: the activation segment is driven into every array of
        // a column partition; each array converts its m output columns;
        // row partitions are partial sums combined by shift-add/DPU adds.
        let stage_arrays = arrays.len();
        mapped_ops.push(MappedOp {
            name: op.name.clone(),
            layer: op.layer,
            rows: op.rows,
            cols: op.cols,
            tiles: row_parts * col_parts,
            arrays,
            stage_arrays,
            stages: 1,
            convs_per_array: m.min(op.rows),
            active_rows: m.min(op.cols),
            partial_adds: col_parts.saturating_sub(1),
            analog_phases: 1,
        });
    }

    ModelMapping {
        strategy: Strategy::Linear,
        model: cfg.name.to_string(),
        m,
        b: 0,
        arrays: next_array,
        placements,
        ops: mapped_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::para_ops;

    #[test]
    fn bert_array_count_closed_form() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        // per layer: 4 * (1024/256)^2 + 2 * (4096/256)*(1024/256) = 64 + 128
        assert_eq!(mm.arrays, 24 * (4 * 16 + 2 * 16 * 4));
        assert_eq!(mm.strategy, Strategy::Linear);
    }

    #[test]
    fn full_utilization_for_multiples() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        assert!((mm.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn op_geometry() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        let wq = &mm.ops[0];
        assert_eq!(wq.stage_arrays, 16);
        assert_eq!(wq.stages, 1);
        assert_eq!(wq.convs_per_array, 256);
        assert_eq!(wq.active_rows, 256);
        assert_eq!(wq.partial_adds, 3); // 4 column partitions
        let ffn1 = mm.ops.iter().find(|o| o.name == "enc0.ffn1").unwrap();
        assert_eq!(ffn1.stage_arrays, 64);
    }

    #[test]
    fn arrays_disjoint_across_ops() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        let mut seen = std::collections::HashSet::new();
        for op in &mm.ops {
            for a in &op.arrays {
                assert!(seen.insert(*a), "array {a} shared in Linear mapping");
            }
        }
    }

    #[test]
    fn tiny_model_padding_accounted() {
        // tiny: d=64 < m=256 -> one array per weight, utilization < 100%
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        assert!(mm.utilization() < 1.0);
        let wq = &mm.ops[0];
        assert_eq!(wq.convs_per_array, 64);
        assert_eq!(wq.active_rows, 64);
    }
}
