//! DenseMap (§III-B2, capacity-optimized): pack multiple block-diagonal
//! *lanes* into each array at distinct diagonal indices, pairing each
//! L-stage lane at diagonal `i` with its R-stage lane at `-i mod lanes`
//! so block rotations cancel (§III-B2a), and folding the Monarch
//! permutations into the factors (§III-B3).
//!
//! Packing rules implemented here:
//! * An m x m array has `lanes = m/b` diagonal slots; slot `i` holds a
//!   run of up to `lanes` blocks at block-positions `(j, (j+i) % lanes)`.
//! * A factor of b blocks splits into `ceil(b/lanes)` lane *chunks*;
//!   chunk `j` of L is paired with chunk `j` of R.
//! * Non-self-inverse diagonal pairs `(i, lanes-i)` co-reside in one
//!   array; the self-inverse indices 0 and lanes/2 are placed in
//!   *different* arrays at the same index (§III-B2a special case).
//! * Pairs round-robin across open arrays so one op's chunks keep the
//!   same stage parallelism as SparseMap; later ops fill the remaining
//!   diagonals of earlier arrays (that co-location is what the
//!   scheduler's contention model serializes).

use super::rotation::{is_self_inverse, pair_index};
use super::{tiles_of, Factor, MappedOp, ModelMapping, Placement, Strategy};
use crate::cim::CimParams;
use crate::model::{MatmulOp, ModelConfig};

/// Free-slot state of one open array during packing.
struct ArrayState {
    /// Unused complementary diagonal pairs (i, lanes - i), i < lanes - i.
    free_pairs: Vec<(usize, usize)>,
    /// Unused self-inverse diagonals (0 and lanes/2).
    free_self: Vec<usize>,
}

impl ArrayState {
    fn new(lanes: usize) -> Self {
        let mut free_pairs = Vec::new();
        let mut free_self = Vec::new();
        for i in 0..lanes {
            let p = pair_index(i, lanes);
            if is_self_inverse(i, lanes) {
                free_self.push(i);
            } else if i < p {
                free_pairs.push((i, p));
            }
        }
        Self {
            free_pairs,
            free_self,
        }
    }
}

/// Dependency-slot rank of an op name (matches `scheduler::layer_slots`).
fn slot_rank(name: &str) -> usize {
    let cross = name.starts_with("xdec");
    let base = if name.ends_with(".wq") {
        0
    } else if name.ends_with(".wk") {
        1
    } else if name.ends_with(".wv") {
        2
    } else if name.ends_with(".wo") {
        3
    } else if name.ends_with(".ffn1") {
        8
    } else {
        9
    };
    base + if cross { 4 } else { 0 }
}

pub fn map(cfg: &ModelConfig, ops: &[MatmulOp], params: &CimParams) -> ModelMapping {
    let m = params.array_dim;
    let d = cfg.d_model;
    let b = cfg.monarch_b();
    assert!(b <= m, "block size must fit the array");
    let lanes = m / b;

    let mut arrays: Vec<ArrayState> = Vec::new();
    let mut placements: Vec<Placement> = Vec::new();
    let mut mapped_ops: Vec<MappedOp> = Vec::new();
    // §Perf: index of free self-inverse slots (diag -> arrays holding
    // one). The naive O(S^2) pair scan dominated the packer (2.9 ms for
    // BERT); this index makes the self-inverse route O(1) amortized.
    let mut self_index: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    // §Perf: arrays that still have free pair slots (scan-free route 1).
    let mut pair_live: Vec<usize> = Vec::new();
    // Round-robin cursor so consecutive chunk pairs land in different
    // arrays (preserving per-op stage parallelism).
    let mut rr = 0usize;

    let place = |placements: &mut Vec<Placement>,
                 array: usize,
                 diag: usize,
                 op: usize,
                 tile: usize,
                 chunk: usize,
                 factor: Factor,
                 blocks: usize| {
        placements.push(Placement {
            op,
            tile,
            factor,
            lane_of_factor: chunk,
            array,
            diag,
            blocks,
            block_dim: b,
            cells: blocks * b * b,
        });
    };

    // Pack in slot-major order (all wq's across layers, then wk's, ...):
    // ops that execute in the same dependency slot of a layer land in
    // different arrays (no intra-slot contention), while arrays are
    // shared across *layers* — whose execution is sequential anyway.
    // This is the alignment argument of §IV-B: DenseMap's intra-array
    // sequentiality coincides with the network's own layer order.
    let mut order: Vec<usize> = (0..ops.len()).collect();
    order.sort_by_key(|&i| (slot_rank(&ops[i].name), ops[i].layer));
    let mut op_array_sets: Vec<Vec<usize>> = vec![Vec::new(); ops.len()];

    // Placements are appended per-op below; op geometry is derived after.
    for &oi in &order {
        let op = &ops[oi];
        let tiles = tiles_of(op, d);
        let chunks = b.div_ceil(lanes);
        let mut op_arrays: Vec<usize> = Vec::new();
        // Arrays already used by this op — chunks spread across distinct
        // arrays to keep SparseMap-level stage parallelism.
        let mut used_by_op: std::collections::HashSet<usize> =
            std::collections::HashSet::new();

        for tile in 0..tiles {
            for chunk in 0..chunks {
                let blocks_here = lanes.min(b - chunk * lanes);
                // 1) try a complementary pair slot in an array this op
                //    does not already occupy, round-robin over the live
                //    list (arrays with free pairs only).
                let mut placed = false;
                if !pair_live.is_empty() {
                    for step in 0..pair_live.len() {
                        let li = (rr + step) % pair_live.len();
                        let ai = pair_live[li];
                        if used_by_op.contains(&ai) {
                            continue;
                        }
                        let (i, p) = arrays[ai]
                            .free_pairs
                            .pop()
                            .expect("live array must have a pair");
                        if arrays[ai].free_pairs.is_empty() {
                            pair_live.swap_remove(li);
                        }
                        place(&mut placements, ai, i, oi, tile, chunk, Factor::Left, blocks_here);
                        place(&mut placements, ai, p, oi, tile, chunk, Factor::Right, blocks_here);
                        op_arrays.push(ai);
                        used_by_op.insert(ai);
                        rr = li + 1;
                        placed = true;
                        break;
                    }
                }
                if placed {
                    continue;
                }
                // 2) self-inverse route: L and R at the same index in two
                //    different arrays (found via the diag index).
                let mut chosen: Option<((usize, usize), (usize, usize))> = None;
                for (&dgi, holders) in self_index.iter() {
                    let mut found: Vec<usize> = Vec::with_capacity(2);
                    for &ai in holders.iter() {
                        if used_by_op.contains(&ai) || found.contains(&ai) {
                            continue;
                        }
                        found.push(ai);
                        if found.len() == 2 {
                            break;
                        }
                    }
                    if found.len() == 2 {
                        chosen = Some(((found[0], dgi), (found[1], dgi)));
                        break;
                    }
                }
                if let Some(((a1, d1), (a2, d2))) = chosen {
                    arrays[a1].free_self.retain(|&x| x != d1);
                    if let Some(pos) = arrays[a2].free_self.iter().position(|&x| x == d2) {
                        arrays[a2].free_self.remove(pos);
                    }
                    for (ai, dgi) in [(a1, d1), (a2, d2)] {
                        if let Some(h) = self_index.get_mut(&dgi) {
                            if let Some(pos) = h.iter().position(|&x| x == ai) {
                                h.swap_remove(pos);
                            }
                        }
                    }
                    place(&mut placements, a1, d1, oi, tile, chunk, Factor::Left, blocks_here);
                    place(&mut placements, a2, d2, oi, tile, chunk, Factor::Right, blocks_here);
                    op_arrays.push(a1);
                    op_arrays.push(a2);
                    used_by_op.insert(a1);
                    used_by_op.insert(a2);
                    continue;
                }
                // 3) open a fresh array and take a pair slot from it.
                arrays.push(ArrayState::new(lanes));
                let ai = arrays.len() - 1;
                for &dgi in &arrays[ai].free_self {
                    self_index.entry(dgi).or_default().push(ai);
                }
                if let Some((i, p)) = arrays[ai].free_pairs.pop() {
                    if !arrays[ai].free_pairs.is_empty() {
                        pair_live.push(ai);
                    }
                    place(&mut placements, ai, i, oi, tile, chunk, Factor::Left, blocks_here);
                    place(&mut placements, ai, p, oi, tile, chunk, Factor::Right, blocks_here);
                    op_arrays.push(ai);
                    used_by_op.insert(ai);
                    rr = ai + 1;
                } else {
                    // lanes <= 2: arrays have only self-inverse slots; put
                    // L here and R in another fresh array.
                    let dgi = arrays[ai].free_self.pop().expect("fresh array has slots");
                    if let Some(h) = self_index.get_mut(&dgi) {
                        if let Some(pos) = h.iter().position(|&x| x == ai) {
                            h.swap_remove(pos);
                        }
                    }
                    place(&mut placements, ai, dgi, oi, tile, chunk, Factor::Left, blocks_here);
                    arrays.push(ArrayState::new(lanes));
                    let aj = arrays.len() - 1;
                    for &d2 in &arrays[aj].free_self {
                        self_index.entry(d2).or_default().push(aj);
                    }
                    if let Some(pos) = arrays[aj].free_self.iter().position(|&x| x == dgi) {
                        arrays[aj].free_self.remove(pos);
                    }
                    if let Some(h) = self_index.get_mut(&dgi) {
                        if let Some(pos) = h.iter().position(|&x| x == aj) {
                            h.swap_remove(pos);
                        }
                    }
                    place(&mut placements, aj, dgi, oi, tile, chunk, Factor::Right, blocks_here);
                    op_arrays.push(ai);
                    op_arrays.push(aj);
                    used_by_op.insert(ai);
                    used_by_op.insert(aj);
                }
            }
        }

        op_arrays.sort_unstable();
        op_arrays.dedup();
        op_array_sets[oi] = op_arrays;
    }

    // Derive per-op execution geometry from the placements.
    // §Perf: one pass over placements, bucketed per op (the per-op filter
    // rescanned all placements O(ops x placements) before).
    let mut left_by_op: Vec<std::collections::HashMap<usize, usize>> =
        vec![Default::default(); ops.len()];
    let mut right_by_op: Vec<std::collections::HashMap<usize, usize>> =
        vec![Default::default(); ops.len()];
    for p in &placements {
        match p.factor {
            Factor::Left => *left_by_op[p.op].entry(p.array).or_insert(0) += 1,
            Factor::Right => *right_by_op[p.op].entry(p.array).or_insert(0) += 1,
            Factor::Dense => {}
        }
    }
    for (oi, op) in ops.iter().enumerate() {
        let tiles = tiles_of(op, d);
        // analog_phases = max lanes of one stage co-resident in one array
        let per_array_left = std::mem::take(&mut left_by_op[oi]);
        let per_array_right = std::mem::take(&mut right_by_op[oi]);
        let phases = per_array_left
            .values()
            .chain(per_array_right.values())
            .copied()
            .max()
            .unwrap_or(1);
        let stage_arrays = per_array_left.len().max(1);

        mapped_ops.push(MappedOp {
            name: op.name.clone(),
            layer: op.layer,
            rows: op.rows,
            cols: op.cols,
            tiles,
            stage_arrays,
            arrays: std::mem::take(&mut op_array_sets[oi]),
            stages: 2,
            convs_per_array: (lanes.min(b) * b).min(b * b),
            active_rows: b,
            partial_adds: (op.cols.div_ceil(d)).saturating_sub(1),
            analog_phases: phases,
        });
    }

    ModelMapping {
        strategy: Strategy::DenseMap,
        model: cfg.name.to_string(),
        m,
        b,
        arrays: arrays.len(),
        placements,
        ops: mapped_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::rotation::net_rotation;
    use crate::model::para_ops;

    fn bert_mapping() -> ModelMapping {
        let cfg = ModelConfig::bert_large();
        map(&cfg, &para_ops(&cfg), &CimParams::default())
    }

    #[test]
    fn far_fewer_arrays_than_linear_and_sparse() {
        // paper Fig. 6a: ~87% fewer than Linear, >73% fewer than SparseMap
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let ops = para_ops(&cfg);
        let lin = super::super::linear::map(&cfg, &ops, &params);
        let sp = super::super::sparse::map(&cfg, &ops, &params);
        let de = map(&cfg, &ops, &params);
        let vs_linear = 1.0 - de.arrays as f64 / lin.arrays as f64;
        let vs_sparse = 1.0 - de.arrays as f64 / sp.arrays as f64;
        assert!(vs_linear > 0.8, "vs linear: {vs_linear}");
        assert!(vs_sparse > 0.7, "vs sparse: {vs_sparse}");
    }

    #[test]
    fn high_utilization() {
        // paper Fig. 6b: DenseMap ~78.8% average (we expect >= 70%)
        let mm = bert_mapping();
        assert!(mm.utilization() > 0.7, "util {}", mm.utilization());
        assert!(mm.utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn diagonals_unique_within_array() {
        let mm = bert_mapping();
        let mut seen = std::collections::HashSet::new();
        for p in &mm.placements {
            assert!(
                seen.insert((p.array, p.diag)),
                "array {} diag {} double-booked",
                p.array,
                p.diag
            );
        }
    }

    #[test]
    fn pairs_cancel_rotation() {
        // For every (op, tile, chunk): the L and R diagonals must satisfy
        // i_L + i_R ≡ 0 (mod lanes).
        let mm = bert_mapping();
        let lanes = mm.m / mm.b;
        let mut left = std::collections::HashMap::new();
        let mut right = std::collections::HashMap::new();
        for p in &mm.placements {
            let key = (p.op, p.tile, p.lane_of_factor);
            match p.factor {
                Factor::Left => {
                    left.insert(key, p.diag);
                }
                Factor::Right => {
                    right.insert(key, p.diag);
                }
                Factor::Dense => panic!("dense placement in DenseMap"),
            }
        }
        assert_eq!(left.len(), right.len());
        for (key, &il) in &left {
            let ir = right[key];
            assert_eq!(
                net_rotation(il, ir, lanes),
                0,
                "unpaired rotation at {key:?}: i_L={il}, i_R={ir}"
            );
        }
    }

    #[test]
    fn self_inverse_pairs_in_different_arrays() {
        let mm = bert_mapping();
        let lanes = mm.m / mm.b;
        let mut by_key = std::collections::HashMap::new();
        for p in &mm.placements {
            by_key
                .entry((p.op, p.tile, p.lane_of_factor))
                .or_insert_with(Vec::new)
                .push(p);
        }
        for (key, ps) in by_key {
            assert_eq!(ps.len(), 2, "pair incomplete at {key:?}");
            if is_self_inverse(ps[0].diag, lanes) {
                assert_ne!(
                    ps[0].array, ps[1].array,
                    "self-inverse pair co-resident at {key:?}"
                );
            }
        }
    }

    #[test]
    fn blocks_conserved() {
        let cfg = ModelConfig::bert_large();
        let ops = para_ops(&cfg);
        let mm = map(&cfg, &ops, &CimParams::default());
        let total: usize = mm.placements.iter().map(|p| p.blocks).sum();
        let want: usize = ops
            .iter()
            .map(|o| tiles_of(o, cfg.d_model) * 2 * cfg.monarch_b())
            .sum();
        assert_eq!(total, want);
    }

    #[test]
    fn ops_share_arrays_colocation() {
        // Capacity packing must co-locate different ops in one array
        // somewhere (that is where DenseMap's sequentiality comes from).
        let mm = bert_mapping();
        let mut per_array_ops: std::collections::HashMap<usize, std::collections::HashSet<usize>> =
            std::collections::HashMap::new();
        for p in &mm.placements {
            per_array_ops.entry(p.array).or_default().insert(p.op);
        }
        assert!(
            per_array_ops.values().any(|s| s.len() > 1),
            "expected at least one array shared by multiple ops"
        );
    }

    #[test]
    fn geometry_fields() {
        let mm = bert_mapping();
        let wq = &mm.ops[0];
        assert_eq!(wq.stages, 2);
        assert_eq!(wq.active_rows, 32);
        assert_eq!(wq.convs_per_array, 256);
        assert!(wq.analog_phases >= 1);
    }
}
