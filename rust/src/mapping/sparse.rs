//! SparseMap (§III-B1, latency-optimized): each Monarch factor's blocks
//! are placed along the main diagonal of as many arrays as needed, the
//! rest zero-padded.
//!
//! With block size b and array dim m, an array holds m/b blocks on its
//! diagonal (disjoint rows *and* columns, so all blocks of an array
//! compute in parallel in a single analog pass). Effective utilization
//! is b/m — the paper's 12.5% example at b=32, m=256 — and each factor
//! of a d x d tile needs ceil(b / (m/b)) = b^2/m arrays.

use super::{Factor, MappedOp, ModelMapping, Placement, Strategy, tiles_of};
use crate::cim::CimParams;
use crate::model::{MatmulOp, ModelConfig};

pub fn map(cfg: &ModelConfig, ops: &[MatmulOp], params: &CimParams) -> ModelMapping {
    let m = params.array_dim;
    let d = cfg.d_model;
    let b = cfg.monarch_b();
    assert!(b <= m, "block size must fit the array");
    let blocks_per_array = m / b;

    let mut placements = Vec::new();
    let mut mapped_ops = Vec::new();
    let mut next_array = 0usize;

    for (oi, op) in ops.iter().enumerate() {
        let tiles = tiles_of(op, d);
        let mut arrays = Vec::new();
        // Each tile contributes two factors (L then R), each with b blocks.
        for tile in 0..tiles {
            for factor in [Factor::Right, Factor::Left] {
                let mut remaining = b;
                let mut lane = 0usize;
                while remaining > 0 {
                    let here = remaining.min(blocks_per_array);
                    placements.push(Placement {
                        op: oi,
                        tile,
                        factor,
                        lane_of_factor: lane,
                        array: next_array,
                        diag: 0,
                        blocks: here,
                        block_dim: b,
                        cells: here * b * b,
                    });
                    arrays.push(next_array);
                    next_array += 1;
                    remaining -= here;
                    lane += 1;
                }
            }
        }
        // Per stage, the factor's arrays all work in parallel; each array
        // converts (blocks_per_array * b) = m columns per token. Only b
        // rows per column are active (one block), giving the reduced ADC
        // resolution (5 b at b=32).
        let arrays_per_factor = b.div_ceil(blocks_per_array);
        mapped_ops.push(MappedOp {
            name: op.name.clone(),
            layer: op.layer,
            rows: op.rows,
            cols: op.cols,
            tiles,
            stage_arrays: tiles * arrays_per_factor,
            arrays,
            stages: 2,
            convs_per_array: (blocks_per_array * b).min(b * b),
            active_rows: b,
            partial_adds: (op.cols.div_ceil(d)).saturating_sub(1),
            analog_phases: 1,
        });
    }

    ModelMapping {
        strategy: Strategy::SparseMap,
        model: cfg.name.to_string(),
        m,
        b,
        arrays: next_array,
        placements,
        ops: mapped_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::para_ops;

    #[test]
    fn bert_array_count_closed_form() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        // b=32, m=256: blocks/array = 8, arrays per factor = 4;
        // per layer tiles: 4 attn (1 tile) + ffn1 (4) + ffn2 (4) = 12 tiles
        // -> 12 tiles * 2 factors * 4 arrays = 96 arrays per layer.
        assert_eq!(mm.arrays, 24 * 96);
    }

    #[test]
    fn utilization_is_b_over_m() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        // exactly b/m = 12.5% (all factor lanes fill their arrays)
        assert!((mm.utilization() - 32.0 / 256.0).abs() < 1e-9);
    }

    #[test]
    fn sparse_uses_half_of_linear_arrays() {
        // paper Fig. 6a: SparseMap needs ~50% of Linear's arrays.
        let params = CimParams::default();
        for cfg in ModelConfig::paper_models() {
            let lin = super::super::linear::map(&cfg, &para_ops(&cfg), &params);
            let sp = map(&cfg, &para_ops(&cfg), &params);
            let ratio = sp.arrays as f64 / lin.arrays as f64;
            assert!(
                (0.45..0.6).contains(&ratio),
                "{}: sparse/linear = {ratio}",
                cfg.name
            );
        }
    }

    #[test]
    fn op_geometry() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let mm = map(&cfg, &para_ops(&cfg), &params);
        let wq = &mm.ops[0];
        assert_eq!(wq.stages, 2);
        assert_eq!(wq.stage_arrays, 4);
        assert_eq!(wq.active_rows, 32); // -> 5b ADC
        assert_eq!(wq.convs_per_array, 256);
        assert_eq!(wq.analog_phases, 1);
    }

    #[test]
    fn blocks_conserved() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let ops = para_ops(&cfg);
        let mm = map(&cfg, &ops, &params);
        let total_blocks: usize = mm.placements.iter().map(|p| p.blocks).sum();
        let want: usize = ops
            .iter()
            .map(|o| tiles_of(o, cfg.d_model) * 2 * cfg.monarch_b())
            .sum();
        assert_eq!(total_blocks, want);
    }
}
