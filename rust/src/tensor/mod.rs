//! Dense matrix substrate: row-major `f32` matrices with the operations
//! the D2S pipeline, functional CIM simulator and tests need.
//!
//! The blocked/parallel matmul lives in [`matmul`]; `Matrix::matmul`
//! dispatches to it. This is a deliberate from-scratch substrate (no BLAS
//! in the offline image) and is one of the §Perf hot paths.

pub mod matmul;

use crate::util::rng::Pcg32;

/// Row-major dense `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Standard-normal entries from a deterministic PRNG.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg32) -> Self {
        Self {
            rows,
            cols,
            data: rng.normal_vec(rows * cols),
        }
    }

    /// Build from a function of (row, col).
    pub fn from_fn<F: FnMut(usize, usize) -> f32>(
        rows: usize,
        cols: usize,
        mut f: F,
    ) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// `self @ other` via the blocked kernel.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        matmul::matmul(self, other)
    }

    /// Matrix-vector product `self @ v`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols, "matvec shape mismatch");
        let mut out = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(v) {
                acc += a * b;
            }
            out[r] = acc;
        }
        out
    }

    /// Copy a `rh x cw` sub-matrix starting at `(r0, c0)`.
    pub fn submatrix(&self, r0: usize, c0: usize, rh: usize, cw: usize) -> Matrix {
        assert!(r0 + rh <= self.rows && c0 + cw <= self.cols, "slice oob");
        let mut out = Matrix::zeros(rh, cw);
        for r in 0..rh {
            out.row_mut(r)
                .copy_from_slice(&self.data[(r0 + r) * self.cols + c0..][..cw]);
        }
        out
    }

    /// Write `block` into this matrix at `(r0, c0)`.
    pub fn set_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for r in 0..block.rows {
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + block.cols].copy_from_slice(block.row(r));
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Relative Frobenius distance `||a-b||_F / ||b||_F`.
    pub fn rel_error(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a - *b) as f64;
            num += d * d;
        }
        num.sqrt() / other.frobenius().max(1e-30)
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Count entries with |x| > eps (utilization accounting).
    pub fn nnz(&self, eps: f32) -> usize {
        self.data.iter().filter(|x| x.abs() > eps).count()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;

    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let mut rng = Pcg32::new(1);
        let a = Matrix::randn(7, 7, &mut rng);
        let i = Matrix::eye(7);
        let p = a.matmul(&i);
        assert!(p.rel_error(&a) < 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::new(2);
        let a = Matrix::randn(5, 9, &mut rng);
        let v: Vec<f32> = rng.normal_vec(9);
        let vm = Matrix::from_vec(9, 1, v.clone());
        let want = a.matmul(&vm);
        let got = a.matvec(&v);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::new(3);
        let a = Matrix::randn(4, 6, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn submatrix_roundtrip() {
        let mut rng = Pcg32::new(4);
        let a = Matrix::randn(8, 8, &mut rng);
        let blk = a.submatrix(2, 4, 3, 2);
        let mut b = Matrix::zeros(8, 8);
        b.set_submatrix(2, 4, &blk);
        assert_eq!(b.submatrix(2, 4, 3, 2), blk);
        assert_eq!(b[(0, 0)], 0.0);
    }

    #[test]
    fn frobenius_known() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn nnz_counts() {
        let m = Matrix::from_vec(2, 2, vec![0.0, 1.0, -2.0, 1e-9]);
        assert_eq!(m.nnz(1e-6), 2);
    }
}
