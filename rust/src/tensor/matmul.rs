//! Matmul kernels: naive reference, cache-blocked single-thread, and a
//! std::thread parallel driver. One of the §Perf hot paths (used by the
//! D2S projection, densification checks and the functional simulator).
//!
//! Layout note: we compute `C = A @ B` with all three row-major. The
//! inner kernel iterates `k` in the middle loop and accumulates along
//! rows of `B`, which keeps every access unit-stride (the classic ikj
//! order) — no transpose needed.

use super::Matrix;

/// Tile edge for the blocked kernel (L1-friendly: 3 * 64^2 * 4B = 48 KiB).
const TILE: usize = 64;

/// Below this many multiply-adds the naive kernel wins (no tiling or
/// threading overhead).
const SMALL_FLOPS: usize = 64 * 64 * 64;

/// Threshold for spawning threads.
const PAR_FLOPS: usize = 256 * 256 * 256;

/// Public entry: picks naive / blocked / parallel by problem size.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let flops = a.rows * a.cols * b.cols;
    if flops <= SMALL_FLOPS {
        matmul_naive(a, b)
    } else if flops <= PAR_FLOPS {
        matmul_blocked(a, b)
    } else {
        matmul_parallel(a, b)
    }
}

/// Reference kernel (ikj order, still unit-stride).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // skips zero-padded rows in sparse layouts
            }
            let brow = b.row(k);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// Cache-blocked kernel.
pub fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_blocked_into(a, b, &mut c, 0, a.rows);
    c
}

/// Blocked kernel over a row range of `A`/`C` (building block for the
/// parallel driver). Writes `C[i0..i1, :] = A[i0..i1, :] @ B`.
fn matmul_blocked_into(a: &Matrix, b: &Matrix, c: &mut Matrix, i0: usize, i1: usize) {
    let (n, p) = (a.cols, b.cols);
    for ii in (i0..i1).step_by(TILE) {
        let ie = (ii + TILE).min(i1);
        for kk in (0..n).step_by(TILE) {
            let ke = (kk + TILE).min(n);
            for jj in (0..p).step_by(TILE) {
                let je = (jj + TILE).min(p);
                for i in ii..ie {
                    let arow = a.row(i);
                    let crow = &mut c.row_mut(i)[jj..je];
                    // NOTE (§Perf): branch-free inner loop — the zero-
                    // skip branch (kept in the naive kernel for sparse
                    // layouts) defeats vectorization here. A 4-way k
                    // unroll was tried and measured SLOWER (indexed
                    // accesses reintroduce bounds checks); see
                    // EXPERIMENTS.md §Perf for the iteration log.
                    for k in kk..ke {
                        let aik = arow[k];
                        let brow = &b.row(k)[jj..je];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Parallel driver: splits rows of `A` across `std::thread` workers.
pub fn matmul_parallel(a: &Matrix, b: &Matrix) -> Matrix {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(a.rows.max(1));
    if threads <= 1 {
        return matmul_blocked(a, b);
    }
    let rows_per = a.rows.div_ceil(threads);
    let mut c = Matrix::zeros(a.rows, b.cols);
    // Split the output buffer into disjoint row chunks; each worker fills
    // its own chunk, so no synchronization is required.
    let chunks: Vec<&mut [f32]> = c.data.chunks_mut(rows_per * b.cols).collect();
    std::thread::scope(|scope| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let i0 = t * rows_per;
            let i1 = (i0 + rows_per).min(a.rows);
            scope.spawn(move || {
                // Each worker computes its disjoint row range into a local
                // buffer, then copies into its chunk of C.
                let mut local = Matrix::zeros(i1 - i0, b.cols);
                let a_slice = Matrix {
                    rows: i1 - i0,
                    cols: a.cols,
                    data: a.data[i0 * a.cols..i1 * a.cols].to_vec(),
                };
                matmul_blocked_into(&a_slice, b, &mut local, 0, i1 - i0);
                chunk.copy_from_slice(&local.data);
            });
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::new(10);
        let a = Matrix::randn(130, 70, &mut rng);
        let b = Matrix::randn(70, 90, &mut rng);
        close(&matmul_blocked(&a, &b), &matmul_naive(&a, &b), 1e-4);
    }

    #[test]
    fn parallel_matches_naive() {
        let mut rng = Pcg32::new(11);
        let a = Matrix::randn(97, 123, &mut rng);
        let b = Matrix::randn(123, 55, &mut rng);
        close(&matmul_parallel(&a, &b), &matmul_naive(&a, &b), 1e-4);
    }

    #[test]
    fn dispatch_consistency_property() {
        forall("matmul kernels agree", 20, |g| {
            let (m, k, n) = (g.usize(1, 40), g.usize(1, 40), g.usize(1, 40));
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
        });
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn associativity_with_identity_padding() {
        // zero rows/cols must not disturb results (sparse-skip path)
        let mut rng = Pcg32::new(12);
        let mut a = Matrix::randn(20, 20, &mut rng);
        for c in 0..20 {
            a[(7, c)] = 0.0;
        }
        let b = Matrix::randn(20, 20, &mut rng);
        close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-4);
    }
}
