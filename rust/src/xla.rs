//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The build image this repo targets does not ship the XLA/PJRT native
//! bundle, so the real `xla` crate cannot be linked. This module keeps
//! the exact API surface `runtime` consumes — `PjRtClient`,
//! `PjRtLoadedExecutable`, `HloModuleProto`, `XlaComputation`,
//! [`Literal`] — with host-side literal handling implemented for real
//! (construction, reshape, readback) and the device/compile entry points
//! returning a descriptive [`XlaError`].
//!
//! Consequences:
//! * `Runtime::new` fails with "PJRT unavailable" instead of a link
//!   error; integration tests and benches detect this and skip the PJRT
//!   path (they exercise the CIM-sim backend instead).
//! * When a PJRT-enabled image is available again, deleting this module
//!   and adding the real `xla` dependency restores the native path —
//!   nothing in `runtime` needs to change.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's surface (`Display` + `Error`,
/// `Send + Sync` so `anyhow::Context` can wrap it).
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can hold (what the repo feeds PJRT).
/// Public only because [`NativeType`]'s methods mention it; treat it as
/// an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Host-side tensor literal: flat payload + dims. Fully functional.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Conversion trait mirroring the real crate's element genericity.
pub trait NativeType: Sized {
    fn wrap(data: &[Self]) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[f32]) -> Payload {
        Payload::F32(data.to_vec())
    }

    fn unwrap(p: &Payload) -> Option<Vec<f32>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[i32]) -> Payload {
        Payload::I32(data.to_vec())
    }

    fn unwrap(p: &Payload) -> Option<Vec<i32>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

impl Literal {
    /// 1-D literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: T::wrap(data),
        }
    }

    /// Reshape to new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.payload.len() {
            return Err(XlaError::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    /// Read back as a flat vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| XlaError::new("literal element type mismatch"))
    }

    /// Split a tuple literal into its elements (stub literals are never
    /// tuples — only device execution produces them).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError::new("stub literal is not a tuple"))
    }
}

/// Parsed HLO module handle (never constructible offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(XlaError::new(format!(
            "PJRT unavailable in this build (xla stub): cannot parse {path:?}"
        )))
    }
}

/// Computation wrapper (constructible from a proto for API parity).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by `execute` (never produced offline).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new("PJRT unavailable in this build (xla stub)"))
    }
}

/// Compiled executable handle (never produced offline).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new("PJRT unavailable in this build (xla stub)"))
    }
}

/// PJRT client. `cpu()` fails deterministically in the offline build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(
            "PJRT unavailable in this build (xla stub): the offline image \
             does not bundle the XLA native libraries — use the CIM-sim \
             backend (`Backend::CimSim`) or rebuild with the real `xla` crate",
        ))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new("PJRT unavailable in this build (xla stub)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn literal_type_checked() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn client_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT unavailable"), "{err}");
    }

    #[test]
    fn hlo_parse_fails_offline() {
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
    }
}
