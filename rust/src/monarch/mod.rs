//! Monarch structured-sparse matrices (paper §II-C, §III-A):
//! block-diagonal factors, the fixed stride permutation, the Frobenius
//! projection (D2S), permutation folding, and rectangular tiling.
//!
//! Index conventions are defined once in `python/compile/kernels/ref.py`
//! and mirrored here; cross-language parity is enforced by the
//! integration tests that run the Rust factors through the AOT-compiled
//! JAX kernels (see `rust/tests/integration_runtime.rs`).

pub mod block_diag;
pub mod fold;
pub mod matrix;
pub mod order_p;
pub mod permutation;
pub mod project;
pub mod rect;

pub use block_diag::BlockDiag;
pub use fold::{FoldedMonarch, StridedBlockDiag};
pub use matrix::MonarchMatrix;
pub use permutation::StridePerm;
pub use project::{monarch_project, project_with_report};
pub use rect::RectMonarch;
