//! The fixed Monarch stride permutation `P` (paper Eq. 1).
//!
//! For `n = b^2` and flat index `i = i1*b + i2`, `P` maps
//! `x[i1*b + i2] -> y[i2*b + i1]` — the transpose of the row-major
//! `(b, b)` view. `P` is an involution (`P^2 = I`), which the folding
//! rewrite `M = (PLP) . P . (PRP)` relies on.

use crate::tensor::Matrix;

/// Stride permutation over `n = b*b` elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StridePerm {
    pub b: usize,
}

impl StridePerm {
    pub fn new(b: usize) -> Self {
        Self { b }
    }

    pub fn n(&self) -> usize {
        self.b * self.b
    }

    /// Image of a single index.
    #[inline]
    pub fn map(&self, i: usize) -> usize {
        let (i1, i2) = (i / self.b, i % self.b);
        i2 * self.b + i1
    }

    /// Apply to a vector: `out[map(i)] = x[i]`.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; x.len()];
        self.apply_into(x, &mut out);
        out
    }

    /// Allocation-free form of [`StridePerm::apply`]: permute `x` into a
    /// caller-owned buffer (every element of `out` is overwritten). This
    /// is the hot-path entry point of the per-token replay loop.
    ///
    /// Gather form — `out` is walked in order (`out[j] = x[map(j)]`),
    /// so the writes are sequential and only the reads stride. Because
    /// `P` is an involution this computes the same permutation as the
    /// scatter form `out[map(i)] = x[i]`.
    pub fn apply_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.n(), "perm length mismatch");
        assert_eq!(out.len(), self.n(), "perm output length mismatch");
        for (j, o) in out.iter_mut().enumerate() {
            *o = x[self.map(j)];
        }
    }

    /// Batched interleaved form of [`StridePerm::apply_into`]: `batch`
    /// lanes stored stride-`batch` (`x[i * batch + l]` is lane `l`'s
    /// element `i`); each lane-block moves as one contiguous chunk, so
    /// the permutation is applied per lane-block with no per-lane loop.
    /// Gather-ordered like [`StridePerm::apply_into`]: destination
    /// lane-blocks are written sequentially.
    pub fn apply_batch_into(&self, x: &[f32], batch: usize, out: &mut [f32]) {
        assert!(batch > 0, "batch must be positive");
        assert_eq!(x.len(), self.n() * batch, "perm length mismatch");
        assert_eq!(out.len(), self.n() * batch, "perm output length mismatch");
        for (j, dst) in out.chunks_exact_mut(batch).enumerate() {
            let i = self.map(j);
            dst.copy_from_slice(&x[i * batch..(i + 1) * batch]);
        }
    }

    /// Apply to each row of a matrix (batched vectors).
    pub fn apply_rows(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(x.rows, x.cols);
        for r in 0..x.rows {
            let src = x.row(r);
            let dst = out.row_mut(r);
            for (i, &v) in src.iter().enumerate() {
                dst[self.map(i)] = v;
            }
        }
        out
    }

    /// Materialize the dense permutation matrix (`P[map(i), i] = 1`),
    /// so that `P @ x == apply(x)`.
    pub fn to_matrix(&self) -> Matrix {
        let n = self.n();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(self.map(i), i)] = 1.0;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn involution() {
        let p = StridePerm::new(5);
        for i in 0..p.n() {
            assert_eq!(p.map(p.map(i)), i);
        }
    }

    #[test]
    fn apply_matches_matrix_form() {
        forall("perm apply == dense P @ x", 20, |g| {
            let b = g.usize(1, 8);
            let p = StridePerm::new(b);
            let x = g.normal_vec(p.n());
            let want = p.to_matrix().matvec(&x);
            let got = p.apply(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn known_small_case() {
        // b=2: [x0, x1, x2, x3] -> [x0, x2, x1, x3]
        let p = StridePerm::new(2);
        assert_eq!(p.apply(&[0.0, 1.0, 2.0, 3.0]), vec![0.0, 2.0, 1.0, 3.0]);
    }

    #[test]
    fn rows_batched_matches_single() {
        let p = StridePerm::new(3);
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let m = Matrix::from_vec(2, 9, [x.clone(), x.clone()].concat());
        let pm = p.apply_rows(&m);
        assert_eq!(pm.row(0), p.apply(&x).as_slice());
        assert_eq!(pm.row(0), pm.row(1));
    }

    #[test]
    fn apply_into_matches_apply() {
        let p = StridePerm::new(4);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.5).collect();
        let mut out = vec![7.0f32; 16]; // stale contents must be overwritten
        p.apply_into(&x, &mut out);
        assert_eq!(out, p.apply(&x));
    }

    #[test]
    fn apply_batch_into_matches_per_lane_apply() {
        let p = StridePerm::new(3);
        for batch in [1usize, 2, 5] {
            let lanes: Vec<Vec<f32>> = (0..batch)
                .map(|l| (0..9).map(|i| (i * (l + 1)) as f32).collect())
                .collect();
            let mut xi = vec![0.0f32; 9 * batch];
            for (l, x) in lanes.iter().enumerate() {
                for (i, &v) in x.iter().enumerate() {
                    xi[i * batch + l] = v;
                }
            }
            let mut out = vec![f32::NAN; 9 * batch];
            p.apply_batch_into(&xi, batch, &mut out);
            for (l, x) in lanes.iter().enumerate() {
                let want = p.apply(x);
                for i in 0..9 {
                    assert_eq!(out[i * batch + l], want[i], "batch {batch} lane {l}");
                }
            }
        }
    }

    #[test]
    fn matrix_is_orthogonal() {
        let p = StridePerm::new(4).to_matrix();
        let prod = p.matmul(&p.transpose());
        assert!(prod.rel_error(&Matrix::eye(16)) < 1e-6);
    }
}
