//! Order-p Monarch matrices (paper §II-C): the general class
//! `M = (Π_{i=1..p} P_i B_i) P_0` with alternating stride permutations
//! `P_i` and block-diagonal factors `B_i` [20]. The paper (like prior
//! work) evaluates p = 2; this module implements the general form so the
//! framework's mapping/scheduling can be extended to deeper
//! factorizations (each extra factor multiplies another `O(n b)` stage
//! at `O(p n^((p+1)/p))` total complexity).
//!
//! Convention: with `p = 2` and both permutations the `b x b` stride
//! permutation, `OrderP` coincides exactly with [`MonarchMatrix`]
//! (`M = P L P R P`), which the tests pin down.

use super::block_diag::BlockDiag;
use super::matrix::MonarchMatrix;
use super::permutation::StridePerm;
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// General order-p Monarch operator over `n = b^2` (all factors share
/// one block size; the stride permutation is the fixed `P`).
#[derive(Clone, Debug)]
pub struct OrderP {
    /// Factors applied right-to-left: `factors[0]` is the innermost
    /// (first after `P_0`); for p = 2 this is `[R, L]`.
    pub factors: Vec<BlockDiag>,
}

impl OrderP {
    pub fn new(factors: Vec<BlockDiag>) -> Self {
        assert!(!factors.is_empty(), "order-p needs at least one factor");
        let b = factors[0].b;
        for f in &factors {
            assert_eq!(f.b, b, "all factors share the block size");
            assert_eq!(f.nblocks, b, "Monarch factors have b blocks");
        }
        Self { factors }
    }

    pub fn randn(p: usize, b: usize, rng: &mut Pcg32) -> Self {
        Self::new((0..p).map(|_| BlockDiag::randn(b, b, rng)).collect())
    }

    pub fn from_monarch(m: &MonarchMatrix) -> Self {
        Self::new(vec![m.r.clone(), m.l.clone()])
    }

    pub fn p(&self) -> usize {
        self.factors.len()
    }

    pub fn b(&self) -> usize {
        self.factors[0].b
    }

    pub fn n(&self) -> usize {
        self.factors[0].n()
    }

    /// Stored parameters: `p * b^3`.
    pub fn params(&self) -> usize {
        self.factors.iter().map(|f| f.params()).sum()
    }

    /// MVM FLOPs: `p * 2 n b` (sub-quadratic; §II-C's
    /// `O(p n^((p+1)/p))` at p = 2).
    pub fn mvm_flops(&self) -> usize {
        self.p() * 2 * self.n() * self.b()
    }

    /// `y = (Π_i P B_i) P x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let perm = StridePerm::new(self.b());
        let mut v = perm.apply(x); // P_0
        for f in &self.factors {
            v = f.matvec(&v);
            v = perm.apply(&v); // P_i
        }
        v
    }

    /// Dense materialization through the factored product.
    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        let mut out = Matrix::zeros(n, n);
        let mut e = vec![0.0f32; n];
        for col in 0..n {
            e[col] = 1.0;
            let y = self.matvec(&e);
            for (row, &v) in y.iter().enumerate() {
                out[(row, col)] = v;
            }
            e[col] = 0.0;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn p2_coincides_with_monarch() {
        forall("order-2 == MonarchMatrix", 15, |g| {
            let b = g.usize(2, 8);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let m = MonarchMatrix::randn(b, &mut rng);
            let op = OrderP::from_monarch(&m);
            let x = rng.normal_vec(m.n());
            let want = m.matvec(&x);
            let got = op.matvec(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 2e-3 * (1.0 + w.abs()));
            }
        });
    }

    #[test]
    fn p1_is_permuted_block_diagonal() {
        let mut rng = Pcg32::new(1);
        let b = 4;
        let bd = BlockDiag::randn(b, b, &mut rng);
        let op = OrderP::new(vec![bd.clone()]);
        let x = rng.normal_vec(16);
        let p = StridePerm::new(b);
        let want = p.apply(&bd.matvec(&p.apply(&x)));
        assert_eq!(op.matvec(&x), want);
    }

    #[test]
    fn higher_order_still_linear_operator() {
        let mut rng = Pcg32::new(2);
        let op = OrderP::randn(3, 4, &mut rng);
        let x = rng.normal_vec(16);
        let y = rng.normal_vec(16);
        let fx = op.matvec(&x);
        let fy = op.matvec(&y);
        let mix: Vec<f32> = x.iter().zip(&y).map(|(a, b)| 3.0 * a - b).collect();
        let fmix = op.matvec(&mix);
        for i in 0..16 {
            assert!((fmix[i] - (3.0 * fx[i] - fy[i])).abs() < 1e-3);
        }
    }

    #[test]
    fn params_and_flops_scale_with_p() {
        let mut rng = Pcg32::new(3);
        for p in 1..=4 {
            let op = OrderP::randn(p, 8, &mut rng);
            assert_eq!(op.params(), p * 8 * 8 * 8);
            assert_eq!(op.mvm_flops(), p * 2 * 64 * 8);
        }
    }

    #[test]
    fn dense_materialization_matches_matvec() {
        let mut rng = Pcg32::new(4);
        let op = OrderP::randn(3, 3, &mut rng);
        let dense = op.to_dense();
        let x = rng.normal_vec(9);
        let want = dense.matvec(&x);
        let got = op.matvec(&x);
        for (a, w) in got.iter().zip(&want) {
            assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn deeper_factorization_keeps_subquadratic_params() {
        // even p = 4 stays far below dense n^2 for realistic b
        let mut rng = Pcg32::new(5);
        let op = OrderP::randn(4, 32, &mut rng);
        assert!(op.params() * 2 < op.n() * op.n());
    }
}
