//! The Monarch matrix `M = P L P R P` (paper Eq. 1) and its operations.

use super::block_diag::BlockDiag;
use super::permutation::StridePerm;
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// A square Monarch matrix of dimension `n = b^2` with block size `b`.
///
/// Layout convention matches `python/compile/kernels/ref.py`:
/// `y[(d,a)] = sum_k L[a][d,k] * sum_c R[k][a,c] * x[(c,k)]`, i.e.
/// `M[(d,a),(c,k)] = L[a][d,k] * R[k][a,c]` (the rank-1 slice identity).
#[derive(Clone, Debug, PartialEq)]
pub struct MonarchMatrix {
    pub l: BlockDiag,
    pub r: BlockDiag,
}

impl MonarchMatrix {
    pub fn new(l: BlockDiag, r: BlockDiag) -> Self {
        assert_eq!(l.b, r.b, "L/R block size mismatch");
        assert_eq!(l.nblocks, l.b, "Monarch requires nblocks == b");
        assert_eq!(r.nblocks, r.b, "Monarch requires nblocks == b");
        Self { l, r }
    }

    pub fn randn(b: usize, rng: &mut Pcg32) -> Self {
        Self::new(BlockDiag::randn(b, b, rng), BlockDiag::randn(b, b, rng))
    }

    pub fn identity(b: usize) -> Self {
        Self::new(BlockDiag::identity(b, b), BlockDiag::identity(b, b))
    }

    pub fn b(&self) -> usize {
        self.l.b
    }

    pub fn n(&self) -> usize {
        self.l.n()
    }

    /// Stored parameter count: `2 b^3 = 2 n sqrt(n)`.
    pub fn params(&self) -> usize {
        self.l.params() + self.r.params()
    }

    /// Multiply-accumulate FLOPs for one MVM: `2 * 2 * n * b`.
    pub fn mvm_flops(&self) -> usize {
        4 * self.n() * self.b()
    }

    /// `y = M x` via the factored form (sub-quadratic).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let p = StridePerm::new(self.b());
        let u = p.apply(x);
        let v = self.r.matvec(&u);
        let w = p.apply(&v);
        let z = self.l.matvec(&w);
        p.apply(&z)
    }

    /// Batched rows (each row an independent vector).
    pub fn matmul_rows(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, x.cols);
        for r in 0..x.rows {
            y.row_mut(r).copy_from_slice(&self.matvec(x.row(r)));
        }
        y
    }

    /// Materialize dense `M` via the slice identity
    /// `M[(d,a),(c,k)] = L[a][d,k] * R[k][a,c]`.
    pub fn to_dense(&self) -> Matrix {
        let b = self.b();
        let n = self.n();
        let mut m = Matrix::zeros(n, n);
        for a in 0..b {
            for k in 0..b {
                for d in 0..b {
                    let lv = self.l.get(a, d, k);
                    if lv == 0.0 {
                        continue;
                    }
                    let row = d * b + a;
                    for c in 0..b {
                        m[(row, c * b + k)] = lv * self.r.get(k, a, c);
                    }
                }
            }
        }
        m
    }

    /// Dense materialization through the factored product
    /// `P Ld P Rd P` — O(n^3), used only to cross-check `to_dense`.
    pub fn to_dense_via_product(&self) -> Matrix {
        let p = StridePerm::new(self.b()).to_matrix();
        let ld = self.l.to_dense();
        let rd = self.r.to_dense();
        p.matmul(&ld).matmul(&p).matmul(&rd).matmul(&p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn dense_forms_agree() {
        forall("slice identity == factored product", 10, |g| {
            let b = g.usize(2, 6);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let m = MonarchMatrix::randn(b, &mut rng);
            let a = m.to_dense();
            let bm = m.to_dense_via_product();
            assert!(a.rel_error(&bm) < 1e-4, "err {}", a.rel_error(&bm));
        });
    }

    #[test]
    fn matvec_matches_dense() {
        forall("monarch matvec == dense @ x", 15, |g| {
            let b = g.usize(2, 8);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let m = MonarchMatrix::randn(b, &mut rng);
            let x = rng.normal_vec(m.n());
            let want = m.to_dense().matvec(&x);
            let got = m.matvec(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 1e-3 * (1.0 + w.abs()));
            }
        });
    }

    #[test]
    fn identity_monarch_is_permutation_product() {
        // L = R = I gives M = P I P I P = P (involution twice) = P
        let m = MonarchMatrix::identity(3);
        let p = StridePerm::new(3).to_matrix();
        assert!(m.to_dense().rel_error(&p) < 1e-6);
    }

    #[test]
    fn params_subquadratic() {
        let mut rng = Pcg32::new(3);
        let m = MonarchMatrix::randn(32, &mut rng); // n = 1024
        assert_eq!(m.params(), 2 * 32 * 32 * 32);
        assert_eq!(m.n() * m.n() / m.params(), 16); // 16x fewer than dense
        assert_eq!(m.mvm_flops(), 4 * 1024 * 32);
    }

    #[test]
    fn linearity() {
        let mut rng = Pcg32::new(4);
        let m = MonarchMatrix::randn(4, &mut rng);
        let x = rng.normal_vec(16);
        let y = rng.normal_vec(16);
        let xy: Vec<f32> = x.iter().zip(&y).map(|(a, b)| 2.0 * a - b).collect();
        let fx = m.matvec(&x);
        let fy = m.matvec(&y);
        let fxy = m.matvec(&xy);
        for i in 0..16 {
            assert!((fxy[i] - (2.0 * fx[i] - fy[i])).abs() < 1e-3);
        }
    }
}
