//! D2S transformation (paper §III-A): Frobenius-optimal projection of a
//! dense matrix onto the Monarch class by per-slice rank-1 SVD.
//!
//! By the slice identity `M[(d,a),(c,k)] = L[a][d,k] * R[k][a,c]`, each
//! `b x b` slice `A^(a,k)[d,c] = W[(d,a),(c,k)]` of a Monarch matrix is
//! rank-1; the projection solves `min ||W - M||_F` slice-by-slice with
//! truncated SVD (Dao et al. 2022). Twin of `python/compile/d2s.py`.

use super::block_diag::BlockDiag;
use super::matrix::MonarchMatrix;
use crate::linalg::rank1_svd;
use crate::tensor::Matrix;

/// Project dense `w` (n x n, n = b^2) onto the Monarch class.
pub fn monarch_project(w: &Matrix) -> MonarchMatrix {
    assert_eq!(w.rows, w.cols, "D2S projection requires a square matrix");
    let n = w.rows;
    let b = (n as f64).sqrt().round() as usize;
    assert_eq!(b * b, n, "dimension must be a perfect square, got {n}");

    let mut l = BlockDiag::zeros(b, b);
    let mut r = BlockDiag::zeros(b, b);
    let mut slice = Matrix::zeros(b, b);
    for a in 0..b {
        for k in 0..b {
            // slice[d, c] = W[(d, a), (c, k)] = W[d*b + a, c*b + k]
            for d in 0..b {
                for c in 0..b {
                    slice[(d, c)] = w[(d * b + a, c * b + k)];
                }
            }
            let r1 = rank1_svd(&slice);
            let s = r1.sigma.max(0.0).sqrt();
            for d in 0..b {
                l.set(a, d, k, s * r1.u[d]);
            }
            for c in 0..b {
                r.set(k, a, c, s * r1.v[c]);
            }
        }
    }
    MonarchMatrix::new(l, r)
}

/// Relative Frobenius projection error `||W - proj(W)||_F / ||W||_F`.
pub fn projection_error(w: &Matrix) -> f64 {
    let m = monarch_project(w).to_dense();
    m.rel_error(w) * w.frobenius() / w.frobenius().max(1e-30) // == rel err
}

/// Per-slice residual spectrum report (diagnostics for DESIGN ablations).
#[derive(Clone, Debug)]
pub struct ProjectionReport {
    pub rel_error: f64,
    pub worst_slice_error: f64,
    pub mean_slice_error: f64,
}

pub fn project_with_report(w: &Matrix) -> (MonarchMatrix, ProjectionReport) {
    let m = monarch_project(w);
    let dense = m.to_dense();
    let b = m.b();
    let mut worst = 0.0f64;
    let mut total = 0.0f64;
    for a in 0..b {
        for k in 0..b {
            let mut err = 0.0f64;
            let mut nrm = 0.0f64;
            for d in 0..b {
                for c in 0..b {
                    let wv = w[(d * b + a, c * b + k)] as f64;
                    let dv = dense[(d * b + a, c * b + k)] as f64;
                    err += (wv - dv) * (wv - dv);
                    nrm += wv * wv;
                }
            }
            let rel = (err / nrm.max(1e-30)).sqrt();
            worst = worst.max(rel);
            total += rel;
        }
    }
    let report = ProjectionReport {
        rel_error: dense.rel_error(w),
        worst_slice_error: worst,
        mean_slice_error: total / (b * b) as f64,
    };
    (m, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn exact_recovery_of_monarch_input() {
        forall("project(monarch) == monarch", 10, |g| {
            let b = g.usize(2, 6);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let m = MonarchMatrix::randn(b, &mut rng);
            let dense = m.to_dense();
            let back = monarch_project(&dense).to_dense();
            assert!(
                back.rel_error(&dense) < 1e-3,
                "recovery error {}",
                back.rel_error(&dense)
            );
        });
    }

    #[test]
    fn projection_never_worse_than_zero() {
        forall("||W - proj|| <= ||W||", 10, |g| {
            let b = g.usize(2, 5);
            let n = b * b;
            let data = g.normal_vec(n * n);
            let w = Matrix::from_vec(n, n, data);
            let m = monarch_project(&w).to_dense();
            assert!(m.sub(&w).frobenius() <= w.frobenius() * (1.0 + 1e-5));
        });
    }

    #[test]
    fn near_monarch_projects_better_than_noise() {
        let mut rng = Pcg32::new(7);
        let b = 8;
        let m = MonarchMatrix::randn(b, &mut rng).to_dense();
        let noise = Matrix::randn(64, 64, &mut rng);
        let near = m.add(&noise.scale(0.05));
        let (_, rep_near) = project_with_report(&near);
        let (_, rep_noise) = project_with_report(&noise);
        assert!(rep_near.rel_error < rep_noise.rel_error);
    }

    #[test]
    fn parity_with_python_small_case() {
        // Same convention as compile/d2s.py: a matrix whose slices are
        // rank-1 projects with ~zero error.
        let b = 3;
        let n = b * b;
        let mut rng = Pcg32::new(8);
        let u = Matrix::randn(b * b, b, &mut rng); // u[(a,k), d]
        let v = Matrix::randn(b * b, b, &mut rng); // v[(a,k), c]
        let mut w = Matrix::zeros(n, n);
        for a in 0..b {
            for k in 0..b {
                for d in 0..b {
                    for c in 0..b {
                        w[(d * b + a, c * b + k)] =
                            u[(a * b + k, d)] * v[(a * b + k, c)];
                    }
                }
            }
        }
        let got = monarch_project(&w).to_dense();
        assert!(got.rel_error(&w) < 1e-4);
    }

    #[test]
    fn report_fields_consistent() {
        let mut rng = Pcg32::new(9);
        let w = Matrix::randn(16, 16, &mut rng);
        let (_, rep) = project_with_report(&w);
        assert!(rep.mean_slice_error <= rep.worst_slice_error + 1e-12);
        assert!(rep.rel_error > 0.0 && rep.rel_error <= 1.0 + 1e-6);
    }
}
