//! Block-diagonal matrix storage and multiply — the `L` and `R` factors
//! of a Monarch matrix, and the unit the CIM mapping strategies place
//! onto crossbar arrays.

use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// `nblocks` dense `b x b` blocks on the diagonal of an
/// `(nblocks*b) x (nblocks*b)` logical matrix. Block `k` is stored
/// row-major at `data[k * b * b ..]` — the same `(nb, b, b)` layout as
/// `python/compile/kernels/ref.py`.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockDiag {
    pub b: usize,
    pub nblocks: usize,
    pub data: Vec<f32>,
}

impl BlockDiag {
    pub fn zeros(nblocks: usize, b: usize) -> Self {
        Self {
            b,
            nblocks,
            data: vec![0.0; nblocks * b * b],
        }
    }

    pub fn randn(nblocks: usize, b: usize, rng: &mut Pcg32) -> Self {
        Self {
            b,
            nblocks,
            data: rng.normal_vec(nblocks * b * b),
        }
    }

    /// Logical dimension `nblocks * b`.
    pub fn n(&self) -> usize {
        self.nblocks * self.b
    }

    /// Number of stored (non-structurally-zero) parameters.
    pub fn params(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn block(&self, k: usize) -> &[f32] {
        &self.data[k * self.b * self.b..(k + 1) * self.b * self.b]
    }

    #[inline]
    pub fn block_mut(&mut self, k: usize) -> &mut [f32] {
        let bb = self.b * self.b;
        &mut self.data[k * bb..(k + 1) * bb]
    }

    #[inline]
    pub fn get(&self, k: usize, r: usize, c: usize) -> f32 {
        self.data[(k * self.b + r) * self.b + c]
    }

    #[inline]
    pub fn set(&mut self, k: usize, r: usize, c: usize, v: f32) {
        self.data[(k * self.b + r) * self.b + c] = v;
    }

    /// Extract block `k` as a Matrix.
    pub fn block_matrix(&self, k: usize) -> Matrix {
        Matrix::from_vec(self.b, self.b, self.block(k).to_vec())
    }

    /// `y = B x` where `x.len() == n()`: block `k` maps segment `k`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n(), "block-diag matvec shape mismatch");
        let b = self.b;
        let mut y = vec![0.0f32; x.len()];
        for k in 0..self.nblocks {
            let blk = self.block(k);
            let xs = &x[k * b..(k + 1) * b];
            let ys = &mut y[k * b..(k + 1) * b];
            for d in 0..b {
                let row = &blk[d * b..(d + 1) * b];
                let mut acc = 0.0f32;
                for (w, xv) in row.iter().zip(xs) {
                    acc += w * xv;
                }
                ys[d] = acc;
            }
        }
        y
    }

    /// Batched rows: `Y[r] = B X[r]` for each row of `X` (cols == n()).
    pub fn matmul_rows(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.n());
        let mut y = Matrix::zeros(x.rows, x.cols);
        for r in 0..x.rows {
            let out = self.matvec(x.row(r));
            y.row_mut(r).copy_from_slice(&out);
        }
        y
    }

    /// Materialize the dense `n x n` matrix.
    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        let b = self.b;
        let mut m = Matrix::zeros(n, n);
        for k in 0..self.nblocks {
            for r in 0..b {
                for c in 0..b {
                    m[(k * b + r, k * b + c)] = self.get(k, r, c);
                }
            }
        }
        m
    }

    /// All-identity blocks.
    pub fn identity(nblocks: usize, b: usize) -> Self {
        let mut bd = Self::zeros(nblocks, b);
        for k in 0..nblocks {
            for i in 0..b {
                bd.set(k, i, i, 1.0);
            }
        }
        bd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn matvec_matches_dense() {
        forall("blockdiag matvec == dense", 25, |g| {
            let nb = g.usize(1, 6);
            let b = g.usize(1, 6);
            let mut rng = crate::util::rng::Pcg32::new(g.usize(0, 1 << 30) as u64);
            let bd = BlockDiag::randn(nb, b, &mut rng);
            let x = rng.normal_vec(bd.n());
            let want = bd.to_dense().matvec(&x);
            let got = bd.matvec(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn identity_is_noop() {
        let bd = BlockDiag::identity(3, 4);
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(bd.matvec(&x), x);
    }

    #[test]
    fn params_counts_stored_entries() {
        let bd = BlockDiag::zeros(8, 32);
        assert_eq!(bd.params(), 8 * 32 * 32);
        assert_eq!(bd.n(), 256);
    }

    #[test]
    fn block_roundtrip() {
        let mut bd = BlockDiag::zeros(2, 2);
        bd.set(1, 0, 1, 7.0);
        assert_eq!(bd.get(1, 0, 1), 7.0);
        assert_eq!(bd.block_matrix(1)[(0, 1)], 7.0);
        assert_eq!(bd.block(0), &[0.0; 4]);
    }

    #[test]
    fn matmul_rows_batches() {
        let mut rng = crate::util::rng::Pcg32::new(9);
        let bd = BlockDiag::randn(3, 3, &mut rng);
        let x = Matrix::randn(4, 9, &mut rng);
        let y = bd.matmul_rows(&x);
        for r in 0..4 {
            let single = bd.matvec(x.row(r));
            assert_eq!(y.row(r), single.as_slice());
        }
    }
}
