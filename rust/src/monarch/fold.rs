//! Permutation folding (paper §III-B3): rewrite `M = P L P R P` as
//! `M = (P L P) · P · (P R P)`, embedding the outer permutations into the
//! factor structure so execution needs **one** explicit permutation step
//! instead of three.
//!
//! The conjugated factors are *strided* block-diagonals:
//!
//! * `S_R = P R P` has `S_R[a*b + k, c*b + k] = R^(k)[a, c]` — block `k`
//!   lives on rows/cols congruent to `k (mod b)`.
//! * `S_L = P L P` has `S_L[d*b + a, k*b + a] = L^(a)[d, k]` — block `a`
//!   lives on rows/cols congruent to `a (mod b)`.
//!
//! Each strided block is still a dense `b x b` unit occupying disjoint
//! rows/columns, so the CIM mapping strategies place folded factors
//! exactly like plain block-diagonals; only the scheduler's address
//! generation changes (strided row/col activation). On hardware this is
//! what lets ADC multiplexing walk bitlines in-order (§III-B3).

use super::block_diag::BlockDiag;
use super::matrix::MonarchMatrix;
use super::permutation::StridePerm;
use crate::tensor::Matrix;

/// A block-diagonal conjugated by the stride permutation: logical blocks
/// on strided index sets.
#[derive(Clone, Debug, PartialEq)]
pub struct StridedBlockDiag {
    /// Underlying blocks; block `k` acts on indices `{ i : i % b == k }`.
    pub inner: BlockDiag,
}

impl StridedBlockDiag {
    /// `y[r*b + k] = sum_c inner[k][r, c] * x[c*b + k]`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let b = self.inner.b;
        assert_eq!(x.len(), self.inner.n(), "strided matvec shape mismatch");
        let mut y = vec![0.0f32; x.len()];
        for k in 0..self.inner.nblocks {
            let blk = self.inner.block(k);
            for r in 0..b {
                let row = &blk[r * b..(r + 1) * b];
                let mut acc = 0.0f32;
                for (c, w) in row.iter().enumerate() {
                    acc += w * x[c * b + k];
                }
                y[r * b + k] = acc;
            }
        }
        y
    }

    /// Dense materialization (tests / mapping diagnostics).
    pub fn to_dense(&self) -> Matrix {
        let p = StridePerm::new(self.inner.b).to_matrix();
        p.matmul(&self.inner.to_dense()).matmul(&p)
    }
}

/// Folded Monarch operator: `M = S_L · P · S_R` with one explicit
/// permutation (vs three in the unfolded form).
#[derive(Clone, Debug, PartialEq)]
pub struct FoldedMonarch {
    pub sl: StridedBlockDiag,
    pub sr: StridedBlockDiag,
}

/// Number of explicit permutation passes in each execution form —
/// the quantity §III-B3 reduces from 3 to 1.
pub const PERMS_UNFOLDED: usize = 3;
pub const PERMS_FOLDED: usize = 1;

impl FoldedMonarch {
    pub fn from_monarch(m: &MonarchMatrix) -> Self {
        Self {
            sl: StridedBlockDiag { inner: m.l.clone() },
            sr: StridedBlockDiag { inner: m.r.clone() },
        }
    }

    pub fn b(&self) -> usize {
        self.sl.inner.b
    }

    /// Apply with a single explicit permutation step.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let p = StridePerm::new(self.b());
        let t = self.sr.matvec(x);
        let t = p.apply(&t);
        self.sl.matvec(&t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn folded_equals_unfolded() {
        forall("folded matvec == monarch matvec", 15, |g| {
            let b = g.usize(2, 8);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let m = MonarchMatrix::randn(b, &mut rng);
            let f = FoldedMonarch::from_monarch(&m);
            let x = rng.normal_vec(m.n());
            let want = m.matvec(&x);
            let got = f.matvec(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 1e-3 * (1.0 + w.abs()), "{a} vs {w}");
            }
        });
    }

    #[test]
    fn strided_dense_structure() {
        // S_R[a*b + k, c*b + k] = R[k][a, c]; all other entries zero.
        let mut rng = Pcg32::new(5);
        let b = 3;
        let r = BlockDiag::randn(b, b, &mut rng);
        let s = StridedBlockDiag { inner: r.clone() };
        let dense = s.to_dense();
        for i in 0..9 {
            for j in 0..9 {
                let (a, k) = (i / b, i % b);
                let (c, k2) = (j / b, j % b);
                let want = if k == k2 { r.get(k, a, c) } else { 0.0 };
                assert!((dense[(i, j)] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn strided_matvec_matches_dense() {
        let mut rng = Pcg32::new(6);
        let s = StridedBlockDiag {
            inner: BlockDiag::randn(4, 4, &mut rng),
        };
        let x = rng.normal_vec(16);
        let want = s.to_dense().matvec(&x);
        let got = s.matvec(&x);
        for (a, w) in got.iter().zip(&want) {
            assert!((a - w).abs() < 1e-4);
        }
    }

    #[test]
    fn permutation_count_reduction() {
        assert_eq!(PERMS_UNFOLDED - PERMS_FOLDED, 2);
    }
}
