//! Rectangular parameterized matmuls as tiled Monarch operators.
//!
//! The paper factorizes square `n x n` weights; transformer FFN layers are
//! rectangular (`d -> 4d -> d`). Following §III-B2 ("partitions of a
//! single large matrix that has been partitioned to match array
//! dimensions") we partition a `rows x cols` weight into square `n x n`
//! tiles (zero-padding the remainder) and factorize each tile
//! independently. `y = W x` becomes a tile-grid of Monarch applies with
//! row-wise accumulation.

use super::matrix::MonarchMatrix;
use super::project::monarch_project;
use crate::tensor::Matrix;

/// A `rows x cols` operator stored as a grid of `n x n` Monarch tiles.
#[derive(Clone, Debug)]
pub struct RectMonarch {
    pub rows: usize,
    pub cols: usize,
    /// Tile dimension (`b^2`).
    pub n: usize,
    /// Row-major grid: `tiles[tr * tile_cols + tc]`.
    pub tiles: Vec<MonarchMatrix>,
}

impl RectMonarch {
    pub fn tile_rows(&self) -> usize {
        self.rows.div_ceil(self.n)
    }

    pub fn tile_cols(&self) -> usize {
        self.cols.div_ceil(self.n)
    }

    /// D2S a dense rectangular weight with tile dimension `n` (= b^2).
    pub fn from_dense(w: &Matrix, n: usize) -> Self {
        let b = (n as f64).sqrt().round() as usize;
        assert_eq!(b * b, n, "tile dim must be a perfect square");
        let tr = w.rows.div_ceil(n);
        let tc = w.cols.div_ceil(n);
        let mut tiles = Vec::with_capacity(tr * tc);
        for i in 0..tr {
            for j in 0..tc {
                // zero-padded tile extraction
                let mut tile = Matrix::zeros(n, n);
                let rh = n.min(w.rows - i * n);
                let cw = n.min(w.cols - j * n);
                for r in 0..rh {
                    for c in 0..cw {
                        tile[(r, c)] = w[(i * n + r, j * n + c)];
                    }
                }
                tiles.push(monarch_project(&tile));
            }
        }
        Self {
            rows: w.rows,
            cols: w.cols,
            n,
            tiles,
        }
    }

    /// `y = W x` through the tiled Monarch operators.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols, "rect matvec shape mismatch");
        let n = self.n;
        let (tr, tc) = (self.tile_rows(), self.tile_cols());
        let mut y = vec![0.0f32; self.rows];
        let mut xseg = vec![0.0f32; n];
        for j in 0..tc {
            // zero-padded input segment
            let cw = n.min(self.cols - j * n);
            xseg[..cw].copy_from_slice(&x[j * n..j * n + cw]);
            xseg[cw..].iter_mut().for_each(|v| *v = 0.0);
            for i in 0..tr {
                let part = self.tiles[i * tc + j].matvec(&xseg);
                let rh = n.min(self.rows - i * n);
                for (yo, pv) in y[i * n..i * n + rh].iter_mut().zip(&part) {
                    *yo += pv;
                }
            }
        }
        y
    }

    /// Dense materialization of the whole tiled operator.
    pub fn to_dense(&self) -> Matrix {
        let (tr, tc) = (self.tile_rows(), self.tile_cols());
        let n = self.n;
        let mut w = Matrix::zeros(self.rows, self.cols);
        for i in 0..tr {
            for j in 0..tc {
                let tile = self.tiles[i * tc + j].to_dense();
                let rh = n.min(self.rows - i * n);
                let cw = n.min(self.cols - j * n);
                for r in 0..rh {
                    for c in 0..cw {
                        w[(i * n + r, j * n + c)] = tile[(r, c)];
                    }
                }
            }
        }
        w
    }

    /// Total stored parameters across tiles.
    pub fn params(&self) -> usize {
        self.tiles.iter().map(|t| t.params()).sum()
    }

    /// Total MVM FLOPs across tiles.
    pub fn mvm_flops(&self) -> usize {
        self.tiles.iter().map(|t| t.mvm_flops()).sum()
    }

    /// Relative Frobenius error against the original dense weight.
    pub fn rel_error(&self, w: &Matrix) -> f64 {
        self.to_dense().rel_error(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn square_single_tile_matches_projection() {
        let mut rng = Pcg32::new(1);
        let w = Matrix::randn(16, 16, &mut rng);
        let rect = RectMonarch::from_dense(&w, 16);
        let direct = monarch_project(&w);
        assert!(rect.to_dense().rel_error(&direct.to_dense()) < 1e-6);
    }

    #[test]
    fn rect_matvec_matches_dense_materialization() {
        forall("rect matvec == to_dense @ x", 8, |g| {
            let n = 16; // b = 4
            let tr = g.usize(1, 3);
            let tc = g.usize(1, 3);
            let mut rng = Pcg32::new(g.usize(0, 1 << 30) as u64);
            let w = Matrix::randn(tr * n, tc * n, &mut rng);
            let rect = RectMonarch::from_dense(&w, n);
            let x = rng.normal_vec(tc * n);
            let want = rect.to_dense().matvec(&x);
            let got = rect.matvec(&x);
            for (a, w) in got.iter().zip(&want) {
                assert!((a - w).abs() < 1e-3 * (1.0 + w.abs()));
            }
        });
    }

    #[test]
    fn non_multiple_dims_are_padded() {
        let mut rng = Pcg32::new(2);
        let w = Matrix::randn(20, 10, &mut rng); // not multiples of 16
        let rect = RectMonarch::from_dense(&w, 16);
        assert_eq!(rect.tile_rows(), 2);
        assert_eq!(rect.tile_cols(), 1);
        let x = rng.normal_vec(10);
        let y = rect.matvec(&x);
        assert_eq!(y.len(), 20);
    }

    #[test]
    fn exact_on_blockwise_monarch_input() {
        // A dense matrix assembled from Monarch tiles round-trips.
        let mut rng = Pcg32::new(3);
        let n = 16;
        let m00 = MonarchMatrix::randn(4, &mut rng);
        let m01 = MonarchMatrix::randn(4, &mut rng);
        let mut w = Matrix::zeros(n, 2 * n);
        w.set_submatrix(0, 0, &m00.to_dense());
        w.set_submatrix(0, n, &m01.to_dense());
        let rect = RectMonarch::from_dense(&w, n);
        assert!(rect.rel_error(&w) < 1e-3);
    }

    #[test]
    fn ffn_shape_params_reduction() {
        // d=64 -> 4d=256: params 4 * (2 * 8^3) vs dense 64*256.
        let mut rng = Pcg32::new(4);
        let w = Matrix::randn(256, 64, &mut rng);
        let rect = RectMonarch::from_dense(&w, 64);
        assert_eq!(rect.tiles.len(), 4);
        assert_eq!(rect.params(), 4 * 2 * 8 * 8 * 8);
        assert!(rect.params() < 256 * 64);
    }
}
