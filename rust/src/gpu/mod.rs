//! Analytical GPU comparator (paper §IV: NVIDIA RTX 3090 Ti).
//!
//! We have no GPU in this environment; Fig. 7 only uses the GPU as a
//! reference bar, so we model the token-by-token (decode-style,
//! memory-bound) regime the paper's introduction motivates: every decode
//! step streams all resident weights through HBM, so
//! `t_token ~= bytes(params) / (BW * eff)`, plus a compute-bound floor.
//! Energy = board power * latency. Constants below are the public
//! RTX 3090 Ti specs; the efficiency factor is calibrated so Linear-CIM
//! vs GPU lands near the paper's 16.2x for BERT (DESIGN.md §1).

use crate::model::{count_report, ModelConfig};

/// RTX 3090 Ti-class analytical model.
#[derive(Clone, Debug)]
pub struct GpuParams {
    /// HBM bandwidth in GB/s.
    pub mem_bw_gbs: f64,
    /// Achievable fraction of peak bandwidth in the decode regime.
    pub mem_eff: f64,
    /// fp16 tensor throughput (TFLOP/s).
    pub peak_tflops: f64,
    /// Achievable fraction of peak compute.
    pub compute_eff: f64,
    /// Board power (W).
    pub power_w: f64,
    /// Bytes per weight element (fp16).
    pub bytes_per_param: f64,
}

impl Default for GpuParams {
    fn default() -> Self {
        Self {
            mem_bw_gbs: 1008.0, // 3090 Ti spec
            mem_eff: 0.65,
            peak_tflops: 160.0, // fp16 tensor w/ FP16 accumulate
            compute_eff: 0.3,
            power_w: 450.0,
            bytes_per_param: 2.0,
        }
    }
}

/// Per-token and full-sequence GPU cost for a model's parameterized path.
#[derive(Clone, Debug)]
pub struct GpuCost {
    pub model: String,
    pub per_token_ns: f64,
    pub total_ns: f64,
    pub total_nj: f64,
}

/// Roofline cost of running `cfg`'s parameterized matmuls on the GPU,
/// token-by-token over the full sequence.
pub fn gpu_cost(cfg: &ModelConfig, gpu: &GpuParams) -> GpuCost {
    let counts = count_report(cfg);
    let params_bytes = counts.dense_para_params as f64 * gpu.bytes_per_param;
    // memory-bound: stream all weights once per token
    let t_mem_ns = params_bytes / (gpu.mem_bw_gbs * gpu.mem_eff); // B / (GB/s) = ns
    // compute-bound floor: para flops for one token
    let flops_token = counts.dense_para_flops as f64 / cfg.seq as f64;
    let t_compute_ns = flops_token / (gpu.peak_tflops * gpu.compute_eff * 1e3);
    let per_token_ns = t_mem_ns.max(t_compute_ns);
    let total_ns = per_token_ns * cfg.seq as f64;
    GpuCost {
        model: cfg.name.to_string(),
        per_token_ns,
        total_ns,
        // ns * W = nJ (1e-9 s * W = 1e-9 J)
        total_nj: total_ns * gpu.power_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimParams;
    use crate::mapping::Strategy;
    use crate::scheduler::timing::cost_report;

    #[test]
    fn decode_is_memory_bound() {
        let gpu = GpuParams::default();
        let cfg = ModelConfig::bert_large();
        let c = gpu_cost(&cfg, &gpu);
        let counts = count_report(&cfg);
        let t_mem = counts.dense_para_params as f64 * 2.0 / (1008.0 * 0.65);
        assert!((c.per_token_ns - t_mem).abs() / t_mem < 1e-9);
    }

    #[test]
    fn energy_is_power_times_latency() {
        let gpu = GpuParams::default();
        let cfg = ModelConfig::bert_large();
        let c = gpu_cost(&cfg, &gpu);
        // ns * W = nJ
        assert!((c.total_nj - c.total_ns * gpu.power_w).abs() / c.total_nj < 1e-9);
    }

    #[test]
    fn fig7_linear_cim_vs_gpu_band() {
        // paper: Linear CIM is 16.2x faster than the GPU for BERT and
        // ~3 orders of magnitude more energy-efficient.
        let gpu = GpuParams::default();
        let cfg = ModelConfig::bert_large();
        let g = gpu_cost(&cfg, &gpu);
        let cim = cost_report(&cfg, &CimParams::default(), Strategy::Linear);
        let speedup = g.total_ns / cim.total.latency.total_ns();
        assert!(
            (8.0..35.0).contains(&speedup),
            "CIM-vs-GPU speedup {speedup} out of band"
        );
        let energy_ratio = g.total_nj / cim.total.energy.total_nj();
        assert!(
            (200.0..20000.0).contains(&energy_ratio),
            "energy ratio {energy_ratio}"
        );
    }
}
