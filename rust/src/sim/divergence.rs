//! Token-level divergence of an analog decode vs the exact path
//! (DESIGN.md §6i): given the teacher-forced logit streams of two
//! engines over the same token window, quantify how far device noise
//! and the ADC resolution cap push the model off the exact trajectory.
//!
//! Metrics (all over the same `tokens` window, scored with
//! [`crate::sim::decode::DecodeEngine::score`]):
//!
//! * **first divergence** — earliest position whose greedy (argmax)
//!   token differs (`None` when every position agrees); the position a
//!   greedy generation would first emit a different token.
//! * **token agreement** — fraction of positions whose argmax agrees.
//! * **logit error** — max-abs and RMS error over every (position,
//!   vocab) logit.
//! * **perplexity delta** — teacher-forced perplexity of the analog
//!   stream minus the exact stream's, using each position's logits
//!   against the next forced token (`positions - 1` targets).
//!
//! At ideal analog settings the two streams are bit-identical by
//! construction, so every metric is exactly zero — pinned by
//! `tests/prop_analog.rs`.

use crate::sim::decode::{argmax, DecodeEngine};

/// Divergence of an analog logit stream from the exact one.
#[derive(Clone, Debug, Default)]
pub struct Divergence {
    /// Positions compared (the scored token window's length).
    pub positions: usize,
    /// Earliest position whose argmax token differs; `None` = full
    /// agreement.
    pub first_divergence: Option<usize>,
    /// Fraction of positions whose argmax token agrees (1.0 = all).
    pub token_agreement: f64,
    /// Max |logit difference| over every (position, vocab) entry.
    pub max_abs_logit_err: f64,
    /// RMS logit difference over every (position, vocab) entry.
    pub rms_logit_err: f64,
    /// Teacher-forced perplexity of the analog stream minus the exact
    /// stream's (positive = noise made the forced window less likely).
    pub ppl_delta: f64,
}

impl Divergence {
    /// Whether the analog stream matched the exact one everywhere —
    /// what ideal analog settings must produce (bit-identity implies
    /// all-zero metrics, so this is `== 0.0`, not a tolerance check).
    pub fn is_exact(&self) -> bool {
        self.first_divergence.is_none()
            && self.max_abs_logit_err == 0.0
            && self.rms_logit_err == 0.0
            && self.ppl_delta == 0.0
    }
}

/// Teacher-forced perplexity of a vocab-strided logit stream: position
/// `p`'s logits predict token `p + 1`, so the window contributes
/// `len - 1` log-probs; `exp(-mean log softmax(target))`. Returns 1.0
/// (the empty-product perplexity) for windows of fewer than two tokens.
pub fn teacher_forced_ppl(logits: &[f32], tokens: &[i32], vocab: usize) -> f64 {
    assert_eq!(logits.len(), tokens.len() * vocab, "vocab-strided stream");
    if tokens.len() < 2 {
        return 1.0;
    }
    let mut nll = 0.0f64;
    for p in 0..tokens.len() - 1 {
        let row = &logits[p * vocab..(p + 1) * vocab];
        let target = (tokens[p + 1].max(0) as usize).min(vocab - 1);
        // log softmax with the usual max-shift for stability
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
        let z: f64 = row.iter().map(|&v| (v as f64 - m).exp()).sum();
        nll -= row[target] as f64 - m - z.ln();
    }
    (nll / (tokens.len() - 1) as f64).exp()
}

/// Compare two vocab-strided teacher-forced logit streams over the same
/// token window. `exact` is the reference; `analog` the stream under
/// test.
pub fn compare_logits(
    exact: &[f32],
    analog: &[f32],
    tokens: &[i32],
    vocab: usize,
) -> Divergence {
    let n = tokens.len();
    assert_eq!(exact.len(), n * vocab, "exact stream must be vocab-strided");
    assert_eq!(analog.len(), n * vocab, "analog stream must be vocab-strided");
    assert!(n > 0, "need at least one scored position");
    let mut first = None;
    let mut agree = 0usize;
    let mut max_abs = 0.0f64;
    let mut sq_sum = 0.0f64;
    for p in 0..n {
        let er = &exact[p * vocab..(p + 1) * vocab];
        let ar = &analog[p * vocab..(p + 1) * vocab];
        if argmax(er) == argmax(ar) {
            agree += 1;
        } else if first.is_none() {
            first = Some(p);
        }
        for (e, a) in er.iter().zip(ar) {
            let d = (*e as f64 - *a as f64).abs();
            max_abs = max_abs.max(d);
            sq_sum += d * d;
        }
    }
    Divergence {
        positions: n,
        first_divergence: first,
        token_agreement: agree as f64 / n as f64,
        max_abs_logit_err: max_abs,
        rms_logit_err: (sq_sum / (n * vocab) as f64).sqrt(),
        ppl_delta: teacher_forced_ppl(analog, tokens, vocab)
            - teacher_forced_ppl(exact, tokens, vocab),
    }
}

/// Score `tokens` teacher-forced on both engines and compare the
/// streams. Both engines are reset by `score`; they must share the same
/// model configuration (same vocab).
pub fn measure_divergence(
    exact: &mut DecodeEngine,
    analog: &mut DecodeEngine,
    tokens: &[i32],
) -> Divergence {
    let vocab = exact.model.cfg.vocab;
    assert_eq!(
        vocab, analog.model.cfg.vocab,
        "engines must share a vocabulary"
    );
    let (e, _) = exact.score(tokens);
    let (a, _) = analog.score(tokens);
    compare_logits(&e, &a, tokens, vocab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_streams_are_exact() {
        let vocab = 4;
        let tokens = [1i32, 2, 0];
        let logits: Vec<f32> = (0..tokens.len() * vocab).map(|i| i as f32 * 0.1).collect();
        let d = compare_logits(&logits, &logits, &tokens, vocab);
        assert!(d.is_exact());
        assert_eq!(d.token_agreement, 1.0);
        assert_eq!(d.positions, 3);
        assert_eq!(d.max_abs_logit_err, 0.0);
        assert_eq!(d.rms_logit_err, 0.0);
        assert_eq!(d.ppl_delta, 0.0);
    }

    #[test]
    fn flipped_argmax_sets_first_divergence() {
        let vocab = 3;
        let tokens = [0i32, 1];
        // position 0 agrees (argmax 2), position 1 flips (2 -> 0)
        let exact = vec![0.0, 0.5, 1.0, 0.0, 0.5, 1.0];
        let analog = vec![0.0, 0.5, 1.0, 2.0, 0.5, 1.0];
        let d = compare_logits(&exact, &analog, &tokens, vocab);
        assert_eq!(d.first_divergence, Some(1));
        assert!((d.token_agreement - 0.5).abs() < 1e-12);
        assert!((d.max_abs_logit_err - 2.0).abs() < 1e-12);
        assert!(d.rms_logit_err > 0.0);
        assert!(!d.is_exact());
    }

    #[test]
    fn teacher_forced_ppl_matches_hand_computation() {
        // one transition, uniform logits: p(target) = 1/vocab, so
        // ppl = vocab exactly
        let vocab = 8;
        let tokens = [3i32, 5];
        let logits = vec![0.0f32; 2 * vocab];
        let ppl = teacher_forced_ppl(&logits, &tokens, vocab);
        assert!((ppl - vocab as f64).abs() < 1e-9);
        // single-token window has no transitions
        assert_eq!(teacher_forced_ppl(&logits[..vocab], &tokens[..1], vocab), 1.0);
    }

    #[test]
    fn ppl_delta_penalizes_wrong_confidence() {
        // analog stream puts high confidence on a wrong next token ->
        // its teacher-forced perplexity (and so the delta) goes up
        let vocab = 4;
        let tokens = [0i32, 2];
        let mut exact = vec![0.0f32; 2 * vocab];
        exact[2] = 4.0; // position 0 confident in the true target 2
        let mut analog = exact.clone();
        analog[2] = 0.0;
        analog[1] = 4.0; // confident in the wrong token
        let d = compare_logits(&exact, &analog, &tokens, vocab);
        assert!(d.ppl_delta > 0.0, "wrong confidence must raise ppl");
    }
}
