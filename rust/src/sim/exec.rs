//! Functional execution of mapped operators on emulated crossbars — the
//! correctness half of the simulator.
//!
//! This module demonstrates, numerically, that the mapping strategies and
//! the scheduler's row-activation/rotation handling compute the *right
//! answer*: programming the factor blocks at their placement coordinates,
//! driving only the scheduled rows, de-rotating lane outputs by the
//! diagonal index, and applying the stride permutation between stages
//! reproduces `MonarchMatrix::matvec` exactly. It also exhibits the
//! §III-C failure mode: activating all rows of a DenseMap array mixes
//! lanes and corrupts the result.
//!
//! Execution is split into two paths:
//!
//! * **Compiled replay** (the hot path, [`FunctionalChip::run_op`] /
//!   [`FunctionalChip::run_op_into`]): every op's per-token work is
//!   resolved once at [`FunctionalChip::program_rect`] time into a
//!   [`ModelPlan`] ([`crate::scheduler::compile_plan`]) — flat pass
//!   tables with pre-rotated column indices — and each token replays the
//!   tables through reusable scratch ([`ExecScratch`]). The steady-state
//!   token loop performs **no per-pass heap allocation** and converts
//!   only the columns the schedule names (O(rows × b) instead of
//!   O(rows × m) per DenseMap pass). Two encodings of each pass exist
//!   ([`ReplayMode`], ISSUE 6): the default **bit-block** path walks
//!   u64 set-bit runs of `row_bits`/`col_bits` — staging inputs with
//!   contiguous block copies and accumulating through
//!   [`Crossbar::mvm_pass_bits`]'s run-zipped inner loop — while the
//!   **index-list** path replays the PR-2 `Vec<usize>` tables through
//!   [`Crossbar::mvm_pass_cols`] as the benchmark baseline and second
//!   audit encoding. Both are bit-identical per lane
//!   (`tests/prop_exec_plan.rs`, including array dims 63/64/65 at the
//!   u64 word boundaries).
//! * **Schedule recompute** (the audit path,
//!   [`FunctionalChip::run_op_recompute`], [`FunctionalChip::run_stage`],
//!   [`FunctionalChip::run_stage_all_rows`]): re-derives
//!   [`crate::scheduler::placement_schedule`] per pass, exactly as the
//!   original checker did. `tests/prop_exec_plan.rs` proves the two
//!   paths bit-identical; the all-rows variant exhibits the negative
//!   model.

use crate::cim::adc;
use crate::cim::crossbar::{quantize_slice, Crossbar};
use crate::cim::noise::{corrupt, AnalogMode};
use crate::cim::CimParams;
use crate::mapping::rotation::rotate_blocks_left;
use crate::mapping::{map_ops, Factor, ModelMapping};
use crate::mapping::Strategy;
use crate::model::{MatmulOp, ModelConfig, OpKind, Stage};
use crate::monarch::{MonarchMatrix, RectMonarch, StridePerm};
use crate::scheduler::plan::linear_tile_geometry;
use crate::scheduler::{compile_plan, placement_schedule, CompiledPass, ModelPlan};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Reusable per-chip scratch: every buffer the per-token replay writes
/// through, allocated once at programming time and overwritten per pass.
#[derive(Clone, Debug)]
struct ExecScratch {
    /// Full-width (m) row-voltage staging buffer; only the rows a pass
    /// drives are (re)written, and only those rows are read back.
    input: Vec<f32>,
    /// Converted-column landing buffer (sized to the widest pass).
    colbuf: Vec<f32>,
    /// d-length Monarch stage vectors (d = b²): zero-padded input
    /// segment, and the P/R/P/L/P pipeline stops.
    xseg: Vec<f32>,
    u: Vec<f32>,
    v: Vec<f32>,
    w: Vec<f32>,
    z: Vec<f32>,
    part: Vec<f32>,
    /// Lane capacity the `b*` buffers below are sized for. The batched
    /// replay grows them on demand (`ensure_batch`), so the steady-state
    /// token loop at a fixed batch width allocates nothing.
    batch: usize,
    /// Stride-B interleaved counterparts of the buffers above: element
    /// `i` of lane `l` lives at `buf[i * batch + l]`.
    binput: Vec<f32>,
    bcolbuf: Vec<f32>,
    bxseg: Vec<f32>,
    bu: Vec<f32>,
    bv: Vec<f32>,
    bw: Vec<f32>,
    bz: Vec<f32>,
    bpart: Vec<f32>,
}

impl ExecScratch {
    fn new(m: usize, d: usize, max_cols: usize) -> Self {
        Self {
            input: vec![0.0; m],
            colbuf: vec![0.0; max_cols],
            xseg: vec![0.0; d],
            u: vec![0.0; d],
            v: vec![0.0; d],
            w: vec![0.0; d],
            z: vec![0.0; d],
            part: vec![0.0; d],
            batch: 0,
            binput: Vec::new(),
            bcolbuf: Vec::new(),
            bxseg: Vec::new(),
            bu: Vec::new(),
            bv: Vec::new(),
            bw: Vec::new(),
            bz: Vec::new(),
            bpart: Vec::new(),
        }
    }

    /// Grow the batched staging/landing buffers to hold `batch` lanes.
    fn ensure_batch(&mut self, m: usize, d: usize, max_cols: usize, batch: usize) {
        if batch <= self.batch {
            return;
        }
        self.binput.resize(m * batch, 0.0);
        self.bcolbuf.resize(max_cols * batch, 0.0);
        for buf in [
            &mut self.bxseg,
            &mut self.bu,
            &mut self.bv,
            &mut self.bw,
            &mut self.bz,
            &mut self.bpart,
        ] {
            buf.resize(d * batch, 0.0);
        }
        self.batch = batch;
    }
}

/// Which encoding of the compiled pass tables the replay walks.
///
/// Outputs are bit-identical either way (`tests/prop_exec_plan.rs`);
/// the modes exist so the bench layer can report the bit-block win over
/// the index baseline and so audits have two independent encodings of
/// the same schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplayMode {
    /// u64 bit-block words with popcnt dense indexing and run-merged
    /// staging/accumulation (`CompiledPass::row_bits`/`col_bits`) — the
    /// hot path.
    #[default]
    BitBlock,
    /// The `Vec<usize>` index lists (`CompiledPass::rows`/`cols`) — the
    /// PR-2 baseline encoding.
    IndexList,
}

/// Replay-time SAR ADC state (DESIGN.md §6i), precomputed at programming
/// time so the hot loop never consults `CimParams`: the resolution cap,
/// the required-bits rule memoized per accumulation depth, and per-array
/// full-scale ranges derived from the programmed conductances.
#[derive(Clone, Debug)]
struct AdcReplay {
    /// Resolution cap (`AnalogMode::adc_bits`).
    bits: u32,
    /// [`adc::required_bits`] memoized over accumulation depths `0..=m`
    /// (a pass's [`CompiledPass::conv_depth`] — cells per bitline, not
    /// driven rows: a whole-lane Monarch pass drives many blocks but
    /// each converted column sums only its own block's `b` cells).
    required: Vec<u32>,
    /// `sqrt(depth)` over `0..=m`: the calibrated (RMS random-walk)
    /// accumulation range of `depth` summed cells, following the paper's
    /// §IV-B value-range operating point rather than the worst-case
    /// linear bound (which would waste the low-bit codes).
    row_scale: Vec<f32>,
    /// Per-array max |conductance| after corruption (1e-12 floor so an
    /// unprogrammed array quantizes zeros to zeros, never NaN).
    full_scale: Vec<f32>,
}

impl AdcReplay {
    fn new(bits: u32, params: &CimParams, crossbars: &[Crossbar]) -> Self {
        let m = params.array_dim;
        Self {
            bits,
            required: (0..=m).map(|r| adc::required_bits(params, r)).collect(),
            row_scale: (0..=m).map(|r| (r as f32).sqrt()).collect(),
            full_scale: crossbars
                .iter()
                .map(|xb| {
                    xb.cells
                        .iter()
                        .fold(0.0f32, |mx, &v| mx.max(v.abs()))
                        .max(1e-12)
                })
                .collect(),
        }
    }

    /// Quantize one pass's converted columns in place — only when the
    /// cap is below the exact-conversion resolution for this pass's
    /// accumulation depth (at or above it the SAR readout is exact, so
    /// the buffer must not be touched: that is the ideal-mode
    /// bit-identity contract).
    #[inline]
    fn apply(&self, pass: &CompiledPass, buf: &mut [f32]) {
        let depth = pass.conv_depth;
        if self.bits >= self.required[depth] {
            return;
        }
        let fs = self.full_scale[pass.array] * self.row_scale[depth];
        quantize_slice(buf, self.bits, fs);
    }
}

/// Analog-realism state of a programmed chip: the mode it was programmed
/// under (for introspection) and the replay-time ADC table, if any.
struct AnalogState {
    mode: AnalogMode,
    adc: Option<AdcReplay>,
}

/// A programmed chip: one crossbar per allocated array, plus the
/// compiled per-token plan and the scratch the replay runs through.
pub struct FunctionalChip {
    pub m: usize,
    pub b: usize,
    pub crossbars: Vec<Crossbar>,
    pub mapping: ModelMapping,
    /// Per-token execution plan, resolved once at programming time.
    pub plan: ModelPlan,
    /// Placement indices grouped per op (insertion order preserved) —
    /// the audit/recompute path's index.
    op_placements: Vec<Vec<usize>>,
    scratch: ExecScratch,
    /// Pass-table encoding the replay iterates (bit-block by default).
    replay_mode: ReplayMode,
    /// Analog realism (None = exact digital replay; DESIGN.md §6i).
    analog: Option<AnalogState>,
}

/// Build a single-op model config/op-list for a d x d Monarch weight.
pub fn single_op(d: usize) -> (ModelConfig, Vec<MatmulOp>) {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = d;
    let op = MatmulOp {
        name: "dec0.wq".to_string(),
        stage: Stage::Decoder,
        layer: 0,
        kind: OpKind::Para,
        rows: d,
        cols: d,
        batch: 1,
    };
    (cfg, vec![op])
}

/// Wrap a square single-tile Monarch as a 1x1 [`RectMonarch`] grid.
fn rect_of(mon: &MonarchMatrix) -> RectMonarch {
    RectMonarch {
        rows: mon.n(),
        cols: mon.n(),
        n: mon.n(),
        tiles: vec![mon.clone()],
    }
}

/// Stage one pass's input rows into the shared staging buffer and run
/// the column-restricted conversion. Only the pass's rows of `input`
/// are written (zeros for the padded tail) and only those are read, so
/// no inter-pass clearing is needed.
///
/// Bit-block mode stages by set-bit *run*: a run's rows `r0..r0+len`
/// carry dense elements `k0..k0+len`, so the `n_in`-covered prefix is
/// one `copy_from_slice` from `x[src + k0..]` and the zero-driven tail
/// one `fill` — no per-row index arithmetic. Index-list mode is the
/// PR-2 per-index loop, kept verbatim as the baseline.
#[inline]
fn replay_pass(
    crossbars: &[Crossbar],
    pass: &CompiledPass,
    mode: ReplayMode,
    adc: Option<&AdcReplay>,
    x: &[f32],
    input: &mut [f32],
    colbuf: &mut [f32],
) -> usize {
    let n = match mode {
        ReplayMode::BitBlock => {
            for (r0, k0, len) in pass.row_bits.runs() {
                let seg = &mut input[r0..r0 + len];
                let filled = pass.n_in.saturating_sub(k0).min(len);
                let s = pass.src + k0;
                seg[..filled].copy_from_slice(&x[s..s + filled]);
                seg[filled..].fill(0.0);
            }
            let n = pass.col_bits.len();
            crossbars[pass.array].mvm_pass_bits(
                input,
                &pass.row_bits,
                &pass.col_bits,
                &mut colbuf[..n],
            );
            n
        }
        ReplayMode::IndexList => {
            for (k, &r) in pass.rows.iter().enumerate() {
                input[r] = if k < pass.n_in { x[pass.src + k] } else { 0.0 };
            }
            let n = pass.cols.len();
            crossbars[pass.array].mvm_pass_cols(
                input,
                &pass.rows,
                &pass.cols,
                &mut colbuf[..n],
            );
            n
        }
    };
    // SAR readout quantization of the bitline accumulation — identical
    // hook in both encodings, before the converted columns leave the
    // landing buffer (None = exact conversion, one skipped check).
    if let Some(a) = adc {
        a.apply(pass, &mut colbuf[..n]);
    }
    n
}

/// Replay one Monarch factor stage: each pass assigns its converted
/// columns into its (disjoint) output segment; the passes of a stage
/// cover the whole d-vector.
fn replay_stage(
    crossbars: &[Crossbar],
    passes: &[CompiledPass],
    mode: ReplayMode,
    adc: Option<&AdcReplay>,
    x: &[f32],
    out: &mut [f32],
    input: &mut [f32],
    colbuf: &mut [f32],
) {
    out.fill(0.0);
    for pass in passes {
        let n = replay_pass(crossbars, pass, mode, adc, x, input, colbuf);
        out[pass.dst..pass.dst + n].copy_from_slice(&colbuf[..n]);
    }
}

/// Batched form of [`replay_pass`]: stage `batch` interleaved input
/// lanes and convert the scheduled columns for all of them in one
/// analog pass. `input` must be exactly `m * batch` long; lane `l` of
/// element `src + k` comes from `x[(src + k) * batch + l]`.
///
/// In bit-block mode a whole row-run's stride-B lanes stage as ONE
/// contiguous `len * batch` block copy (the interleaved layouts of
/// consecutive dense elements and consecutive rows coincide), replacing
/// the per-row copy loop of the index path.
#[inline]
fn replay_pass_batch(
    crossbars: &[Crossbar],
    pass: &CompiledPass,
    mode: ReplayMode,
    adc: Option<&AdcReplay>,
    batch: usize,
    x: &[f32],
    input: &mut [f32],
    colbuf: &mut [f32],
) -> usize {
    let n = match mode {
        ReplayMode::BitBlock => {
            for (r0, k0, len) in pass.row_bits.runs() {
                let seg = &mut input[r0 * batch..(r0 + len) * batch];
                let filled = pass.n_in.saturating_sub(k0).min(len);
                let s = (pass.src + k0) * batch;
                seg[..filled * batch].copy_from_slice(&x[s..s + filled * batch]);
                seg[filled * batch..].fill(0.0);
            }
            let n = pass.col_bits.len();
            crossbars[pass.array].mvm_batch_bits(
                input,
                batch,
                &pass.row_bits,
                &pass.col_bits,
                &mut colbuf[..n * batch],
            );
            n
        }
        ReplayMode::IndexList => {
            for (k, &r) in pass.rows.iter().enumerate() {
                let dst = &mut input[r * batch..(r + 1) * batch];
                if k < pass.n_in {
                    let s = (pass.src + k) * batch;
                    dst.copy_from_slice(&x[s..s + batch]);
                } else {
                    dst.fill(0.0);
                }
            }
            let n = pass.cols.len();
            crossbars[pass.array].mvm_batch_cols(
                input,
                batch,
                &pass.rows,
                &pass.cols,
                &mut colbuf[..n * batch],
            );
            n
        }
    };
    // every lane's conversion goes through the same ADC at the same
    // full-scale — one quantize sweep over the interleaved landing slab
    if let Some(a) = adc {
        a.apply(pass, &mut colbuf[..n * batch]);
    }
    n
}

/// Batched form of [`replay_stage`] over stride-B interleaved lanes.
fn replay_stage_batch(
    crossbars: &[Crossbar],
    passes: &[CompiledPass],
    mode: ReplayMode,
    adc: Option<&AdcReplay>,
    batch: usize,
    x: &[f32],
    out: &mut [f32],
    input: &mut [f32],
    colbuf: &mut [f32],
) {
    out.fill(0.0);
    for pass in passes {
        let n = replay_pass_batch(crossbars, pass, mode, adc, batch, x, input, colbuf);
        out[pass.dst * batch..(pass.dst + n) * batch]
            .copy_from_slice(&colbuf[..n * batch]);
    }
}

impl FunctionalChip {
    /// Program the factors of `ops[i] -> monarchs[i]` (square d x d ops)
    /// according to the mapping's placements. Monarch strategies only;
    /// for Linear or rectangular weights use [`FunctionalChip::program_rect`].
    pub fn program(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        monarchs: &[MonarchMatrix],
        params: &CimParams,
        strategy: Strategy,
    ) -> FunctionalChip {
        assert!(matches!(strategy, Strategy::SparseMap | Strategy::DenseMap));
        let rects: Vec<RectMonarch> = monarchs.iter().map(rect_of).collect();
        Self::program_rect(cfg, ops, &rects, params, strategy)
    }

    /// Program a whole op list whose weights are tile grids of Monarch
    /// operators, under any of the three mapping strategies, and compile
    /// the per-token execution plan.
    ///
    /// * SparseMap/DenseMap: each placement's factor blocks are taken
    ///   from `weights[op].tiles[tile]` and programmed **transposed** at
    ///   their placement coordinates (bitline accumulation computes
    ///   `cells^T @ input`, so storing `B^T` yields `y = B x`).
    /// * Linear: the dense materialization of each weight is cut into
    ///   m x m tiles and programmed transposed, one tile per array — the
    ///   paper's baseline of running the *same* operator un-factored.
    pub fn program_rect(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        weights: &[RectMonarch],
        params: &CimParams,
        strategy: Strategy,
    ) -> FunctionalChip {
        Self::program_rect_analog(cfg, ops, weights, params, strategy, None)
    }

    /// [`FunctionalChip::program_rect`] with opt-in analog realism
    /// (DESIGN.md §6i). With `Some(mode)`:
    ///
    /// * **Programming noise** — after the placements are written, every
    ///   crossbar `i` is corrupted ([`crate::cim::noise::corrupt`]) from
    ///   `Pcg32::stream(mode.seed, i)`, so the corrupted chip is a pure
    ///   function of (weights, mapping, seed) regardless of which worker
    ///   or shard programs it. Skipped entirely when the mode is inert
    ///   (`AnalogMode::corrupts`).
    /// * **ADC cap** — when `mode.adc_bits` is below a pass's
    ///   [`adc::required_bits`], replay quantizes that pass's converted
    ///   columns through the SAR mid-tread model before they leave the
    ///   landing buffer; at or above the required resolution nothing is
    ///   touched (exact conversion).
    ///
    /// `AnalogMode::ideal()` is therefore bit-identical to the plain
    /// path by construction. The schedule-recompute audit path reads the
    /// same (corrupted) cells but never quantizes — it audits the exact
    /// conversion of the programmed chip.
    pub fn program_rect_analog(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        weights: &[RectMonarch],
        params: &CimParams,
        strategy: Strategy,
        analog: Option<&AnalogMode>,
    ) -> FunctionalChip {
        assert_eq!(ops.len(), weights.len(), "one weight grid per op");
        for (op, w) in ops.iter().zip(weights) {
            assert_eq!(
                (op.rows, op.cols),
                (w.rows, w.cols),
                "weight shape mismatch for op {}",
                op.name
            );
        }
        let mapping = map_ops(cfg, ops, params, strategy);
        let m = params.array_dim;
        let b = cfg.monarch_b();
        let mut crossbars: Vec<Crossbar> =
            (0..mapping.arrays).map(|_| Crossbar::new(m)).collect();
        if strategy == Strategy::Linear {
            let denses: Vec<Matrix> = weights.iter().map(|w| w.to_dense()).collect();
            for p in &mapping.placements {
                let op = &mapping.ops[p.op];
                let (rp, cp, rows_here, cols_here) = linear_tile_geometry(op, p.tile, m);
                let tile = denses[p.op].submatrix(rp * m, cp * m, rows_here, cols_here);
                crossbars[p.array].program_block(0, 0, &tile.transpose());
            }
        } else {
            for p in &mapping.placements {
                let rect = &weights[p.op];
                assert_eq!(rect.n, b * b, "tile dim must match d_model");
                let mon = &rect.tiles[p.tile];
                let factor_bd = match p.factor {
                    Factor::Left => &mon.l,
                    Factor::Right => &mon.r,
                    Factor::Dense => unreachable!("dense placement in Monarch mapping"),
                };
                let lanes = (m / b).max(1);
                for j in 0..p.blocks {
                    // global block index within the factor
                    let gblk = p.lane_of_factor * lanes + j;
                    let blk = factor_bd.block_matrix(gblk).transpose();
                    let (r0, c0) = (j * b, ((j + p.diag) % lanes) * b);
                    crossbars[p.array].program_block(r0, c0, &blk);
                }
            }
        }
        // device non-idealities: per-array seeded corruption AFTER all
        // placements are written (gmax sees the full programmed range)
        if let Some(a) = analog {
            if a.corrupts() {
                for (i, xb) in crossbars.iter_mut().enumerate() {
                    corrupt(xb, &a.noise, &mut Pcg32::stream(a.seed, i as u64));
                }
            }
        }
        let analog = analog.map(|a| AnalogState {
            adc: a
                .adc_bits
                .map(|bits| AdcReplay::new(bits, params, &crossbars)),
            mode: a.clone(),
        });
        let mut op_placements: Vec<Vec<usize>> = vec![Vec::new(); mapping.ops.len()];
        for (i, p) in mapping.placements.iter().enumerate() {
            op_placements[p.op].push(i);
        }
        // resolve every op's per-token schedule ONCE — the token loop
        // below is pure index-driven replay
        let plan = compile_plan(&mapping);
        let scratch = ExecScratch::new(m, b * b, plan.max_cols());
        FunctionalChip {
            m,
            b,
            crossbars,
            mapping,
            plan,
            op_placements,
            scratch,
            replay_mode: ReplayMode::default(),
            analog,
        }
    }

    /// The analog mode this chip was programmed under, if any.
    pub fn analog_mode(&self) -> Option<&AnalogMode> {
        self.analog.as_ref().map(|a| &a.mode)
    }

    /// Select which pass-table encoding the compiled replay iterates.
    /// Both modes are bit-identical (property-tested); `IndexList` is
    /// kept for benchmark comparison and as a second audit encoding.
    pub fn set_replay_mode(&mut self, mode: ReplayMode) {
        self.replay_mode = mode;
    }

    /// The pass-table encoding currently driving the compiled replay.
    pub fn replay_mode(&self) -> ReplayMode {
        self.replay_mode
    }

    /// Execute one Monarch factor stage of one op by re-deriving the
    /// schedule per pass. `tile = None` spans every tile's placements
    /// (the original single-tile behaviour); `Some(t)` restricts to one
    /// d x d tile of a rectangular weight. Row activation, column
    /// selection and output rotation all come from the scheduler's
    /// [`placement_schedule`]. Audit path — the compiled plan replays
    /// exactly this computation without the per-pass allocations.
    fn stage_pass(
        &self,
        op_idx: usize,
        tile: Option<usize>,
        factor: Factor,
        x: &[f32],
        honor_schedule: bool,
    ) -> Vec<f32> {
        let b = self.b;
        let lanes = (self.m / b).max(1);
        let n = x.len();
        let dense = self.mapping.strategy == Strategy::DenseMap;
        let walk = dense && honor_schedule;
        let mut out = vec![0.0f32; n];
        for p in self.op_placements[op_idx]
            .iter()
            .map(|&i| &self.mapping.placements[i])
            .filter(|p| p.factor == factor && tile.map_or(true, |t| p.tile == t))
        {
            // Input segment for this lane: blocks [chunk*lanes, ...)
            let base = p.lane_of_factor * lanes;
            let sched = placement_schedule(p, self.m, walk);
            if walk {
                // DenseMap (§III-C): arrays hold several lanes whose
                // cells share columns, so the scheduler walks block-row
                // groups — one pass per block, converting only the
                // lane's own column group. The analog passes pipeline
                // behind the ADC stream (sample-and-hold), which is what
                // `scheduler::timing` models.
                for (j, pass) in sched.passes.iter().enumerate() {
                    let src = (base + j) * b;
                    let mut input = vec![0.0f32; self.m];
                    for (k, &r) in pass.rows.iter().enumerate() {
                        input[r] = x[src + k];
                    }
                    let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
                    for (k, &c) in pass.cols.iter().enumerate() {
                        out[src + k] = cols[c];
                    }
                }
            } else {
                // Whole-lane pass: correct for SparseMap (one lane per
                // array, disjoint rows AND columns); the §III-C naive
                // failure mode for DenseMap (mixes co-resident lanes).
                let pass = &sched.passes[0];
                let mut input = vec![0.0f32; self.m];
                for (k, &r) in pass.rows.iter().enumerate() {
                    input[r] = x[base * b + k];
                }
                let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
                // Block j's output sits at column block (j + diag) %
                // lanes; de-rotate to logical order per the Route command.
                let aligned = rotate_blocks_left(&cols, b, sched.rotation);
                for j in 0..p.blocks {
                    let dst = (base + j) * b;
                    out[dst..dst + b].copy_from_slice(&aligned[j * b..(j + 1) * b]);
                }
            }
        }
        out
    }

    /// Execute one factor stage with the scheduler's row activation
    /// (schedule-recompute audit path).
    pub fn run_stage(&self, op_idx: usize, factor: Factor, x: &[f32]) -> Vec<f32> {
        self.stage_pass(op_idx, None, factor, x, true)
    }

    /// §III-C negative model: drive ALL rows (ignore the schedule).
    pub fn run_stage_all_rows(
        &self,
        op_idx: usize,
        factor: Factor,
        x: &[f32],
    ) -> Vec<f32> {
        self.stage_pass(op_idx, None, factor, x, false)
    }

    /// Full MVM for op `op_idx`: `y = W x` with `x.len() == op.cols` and
    /// `y.len() == op.rows`, via compiled-plan replay. Monarch strategies
    /// run P, R, P, L, P per d x d tile with row-tile accumulation
    /// (mirroring `RectMonarch::matvec` exactly, so results are
    /// bit-comparable); Linear runs dense tile passes with
    /// column-partition partial sums.
    pub fn run_op(&mut self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.mapping.ops[op_idx].rows];
        self.run_op_into(op_idx, x, &mut y);
        y
    }

    /// Allocation-free form of [`FunctionalChip::run_op`]: replay the
    /// compiled plan into a caller-owned output (len == op.rows). This
    /// is the decode engine's per-token entry point — no heap
    /// allocation happens anywhere below it.
    pub fn run_op_into(&mut self, op_idx: usize, x: &[f32], y: &mut [f32]) {
        match self.mapping.strategy {
            Strategy::Linear => self.replay_op_linear(op_idx, x, y),
            _ => self.replay_op_monarch(op_idx, x, y),
        }
    }

    /// Batched MVM: replay the compiled plan once for `batch` stacked
    /// input vectors. `xs`/`ys` are stride-B interleaved (`xs[c * batch
    /// + l]` is lane `l`'s input element `c`), so each analog pass
    /// converts a column-block of activations — the near-free batch
    /// amortization of weight-stationary CIM serving.
    ///
    /// Every lane is **bit-identical** to a [`FunctionalChip::run_op_into`]
    /// call over that lane's vector (same f32 operations in the same
    /// order per lane); `batch == 1` takes the single-stream path
    /// directly (the layouts coincide at B=1).
    pub fn run_op_batch_into(
        &mut self,
        op_idx: usize,
        batch: usize,
        xs: &[f32],
        ys: &mut [f32],
    ) {
        assert!(batch > 0, "batch must be positive");
        if batch == 1 {
            return self.run_op_into(op_idx, xs, ys);
        }
        self.scratch
            .ensure_batch(self.m, self.b * self.b, self.plan.max_cols(), batch);
        match self.mapping.strategy {
            Strategy::Linear => self.replay_op_linear_batch(op_idx, batch, xs, ys),
            _ => self.replay_op_monarch_batch(op_idx, batch, xs, ys),
        }
    }

    /// Allocating convenience form of [`FunctionalChip::run_op_batch_into`].
    pub fn run_op_batch(&mut self, op_idx: usize, batch: usize, xs: &[f32]) -> Vec<f32> {
        let mut ys = vec![0.0f32; self.mapping.ops[op_idx].rows * batch];
        self.run_op_batch_into(op_idx, batch, xs, &mut ys);
        ys
    }

    /// Pre-grow the batched replay scratch to `batch` lanes, so a caller
    /// with a known lane budget (slot pool width, prefill chunk size)
    /// reaches the zero-allocation steady state before its first step
    /// instead of after its widest one. Idempotent; lanes only grow.
    pub fn warm_batch(&mut self, batch: usize) {
        self.scratch
            .ensure_batch(self.m, self.b * self.b, self.plan.max_cols(), batch);
    }

    fn replay_op_linear_batch(&mut self, op_idx: usize, batch: usize, xs: &[f32], ys: &mut [f32]) {
        let op = &self.mapping.ops[op_idx];
        assert_eq!(xs.len(), op.cols * batch, "linear batch input length");
        assert_eq!(ys.len(), op.rows * batch, "linear batch output length");
        ys.fill(0.0);
        let m = self.m;
        let mode = self.replay_mode;
        let FunctionalChip {
            crossbars,
            plan,
            scratch,
            analog,
            ..
        } = self;
        let adc = analog.as_ref().and_then(|a| a.adc.as_ref());
        let max_cols = plan.max_cols();
        let input = &mut scratch.binput[..m * batch];
        let colbuf = &mut scratch.bcolbuf[..max_cols * batch];
        for pass in &plan.ops[op_idx].passes {
            let n =
                replay_pass_batch(&crossbars[..], pass, mode, adc, batch, xs, input, colbuf);
            let seg = &mut ys[pass.dst * batch..(pass.dst + n) * batch];
            for (yo, pv) in seg.iter_mut().zip(&colbuf[..n * batch]) {
                *yo += pv;
            }
        }
    }

    fn replay_op_monarch_batch(
        &mut self,
        op_idx: usize,
        batch: usize,
        xs: &[f32],
        ys: &mut [f32],
    ) {
        let op = &self.mapping.ops[op_idx];
        let d = self.b * self.b;
        assert_eq!(xs.len(), op.cols * batch, "monarch batch input length");
        assert_eq!(ys.len(), op.rows * batch, "monarch batch output length");
        ys.fill(0.0);
        let (op_rows, op_cols) = (op.rows, op.cols);
        let (tr, tc) = (op_rows.div_ceil(d), op_cols.div_ceil(d));
        let perm = StridePerm::new(self.b);
        let m = self.m;
        let mode = self.replay_mode;
        let FunctionalChip {
            crossbars,
            plan,
            scratch,
            analog,
            ..
        } = self;
        let adc = analog.as_ref().and_then(|a| a.adc.as_ref());
        let oplan = &plan.ops[op_idx];
        let max_cols = plan.max_cols();
        let input = &mut scratch.binput[..m * batch];
        let colbuf = &mut scratch.bcolbuf[..max_cols * batch];
        let xseg = &mut scratch.bxseg[..d * batch];
        let u = &mut scratch.bu[..d * batch];
        let v = &mut scratch.bv[..d * batch];
        let w = &mut scratch.bw[..d * batch];
        let z = &mut scratch.bz[..d * batch];
        let part = &mut scratch.bpart[..d * batch];
        for j in 0..tc {
            // zero-padded interleaved input segment (per lane, the same
            // loop structure as the single-stream replay)
            let cw = d.min(op_cols - j * d);
            xseg[..cw * batch].copy_from_slice(&xs[j * d * batch..(j * d + cw) * batch]);
            xseg[cw * batch..].fill(0.0);
            perm.apply_batch_into(xseg, batch, u);
            for i in 0..tr {
                let tile = &oplan.tiles[i * tc + j];
                replay_stage_batch(
                    &crossbars[..],
                    &oplan.passes[tile.right.clone()],
                    mode,
                    adc,
                    batch,
                    u,
                    v,
                    input,
                    colbuf,
                );
                perm.apply_batch_into(v, batch, w);
                replay_stage_batch(
                    &crossbars[..],
                    &oplan.passes[tile.left.clone()],
                    mode,
                    adc,
                    batch,
                    w,
                    z,
                    input,
                    colbuf,
                );
                perm.apply_batch_into(z, batch, part);
                let rh = d.min(op_rows - i * d);
                let seg = &mut ys[i * d * batch..(i * d + rh) * batch];
                for (yo, pv) in seg.iter_mut().zip(&part[..rh * batch]) {
                    *yo += pv;
                }
            }
        }
    }

    fn replay_op_linear(&mut self, op_idx: usize, x: &[f32], y: &mut [f32]) {
        let op = &self.mapping.ops[op_idx];
        assert_eq!(x.len(), op.cols, "linear op input length");
        assert_eq!(y.len(), op.rows, "linear op output length");
        y.fill(0.0);
        let mode = self.replay_mode;
        let FunctionalChip {
            crossbars,
            plan,
            scratch,
            analog,
            ..
        } = self;
        let adc = analog.as_ref().and_then(|a| a.adc.as_ref());
        let ExecScratch { input, colbuf, .. } = scratch;
        // Pass order is placement allocation order (row-partition-major,
        // ascending column partitions), fixing the partial-sum
        // accumulation order (shift-add tree determinism).
        for pass in &plan.ops[op_idx].passes {
            let n = replay_pass(
                &crossbars[..],
                pass,
                mode,
                adc,
                x,
                &mut input[..],
                &mut colbuf[..],
            );
            for (yo, pv) in y[pass.dst..pass.dst + n].iter_mut().zip(&colbuf[..n]) {
                *yo += pv;
            }
        }
    }

    fn replay_op_monarch(&mut self, op_idx: usize, x: &[f32], y: &mut [f32]) {
        let op = &self.mapping.ops[op_idx];
        let d = self.b * self.b;
        assert_eq!(x.len(), op.cols, "monarch op input length");
        assert_eq!(y.len(), op.rows, "monarch op output length");
        y.fill(0.0);
        let (op_rows, op_cols) = (op.rows, op.cols);
        let (tr, tc) = (op_rows.div_ceil(d), op_cols.div_ceil(d));
        let perm = StridePerm::new(self.b);
        let mode = self.replay_mode;
        let FunctionalChip {
            crossbars,
            plan,
            scratch,
            analog,
            ..
        } = self;
        let adc = analog.as_ref().and_then(|a| a.adc.as_ref());
        let oplan = &plan.ops[op_idx];
        let ExecScratch {
            input,
            colbuf,
            xseg,
            u,
            v,
            w,
            z,
            part,
            ..
        } = scratch;
        for j in 0..tc {
            // zero-padded input segment (same loop structure as
            // RectMonarch::matvec for bit-identical accumulation order)
            let cw = d.min(op_cols - j * d);
            xseg[..cw].copy_from_slice(&x[j * d..j * d + cw]);
            xseg[cw..].fill(0.0);
            perm.apply_into(&xseg[..], &mut u[..]);
            for i in 0..tr {
                let tile = &oplan.tiles[i * tc + j];
                replay_stage(
                    &crossbars[..],
                    &oplan.passes[tile.right.clone()],
                    mode,
                    adc,
                    &u[..],
                    &mut v[..],
                    &mut input[..],
                    &mut colbuf[..],
                );
                perm.apply_into(&v[..], &mut w[..]);
                replay_stage(
                    &crossbars[..],
                    &oplan.passes[tile.left.clone()],
                    mode,
                    adc,
                    &w[..],
                    &mut z[..],
                    &mut input[..],
                    &mut colbuf[..],
                );
                perm.apply_into(&z[..], &mut part[..]);
                let rh = d.min(op_rows - i * d);
                for (yo, pv) in y[i * d..i * d + rh].iter_mut().zip(&part[..rh]) {
                    *yo += pv;
                }
            }
        }
    }

    /// Full MVM via per-pass schedule recomputation — the pre-plan
    /// execution path, kept as the audit reference the compiled replay
    /// is property-tested against (`tests/prop_exec_plan.rs`).
    pub fn run_op_recompute(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        match self.mapping.strategy {
            Strategy::Linear => self.recompute_op_linear(op_idx, x),
            _ => self.recompute_op_monarch(op_idx, x),
        }
    }

    fn recompute_op_linear(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let m = self.m;
        let op = &self.mapping.ops[op_idx];
        assert_eq!(x.len(), op.cols, "linear op input length");
        let mut out = vec![0.0f32; op.rows];
        for p in self.op_placements[op_idx]
            .iter()
            .map(|&i| &self.mapping.placements[i])
        {
            let (rp, cp, rows_here, cols_here) = linear_tile_geometry(op, p.tile, m);
            let sched = placement_schedule(p, m, false);
            let pass = &sched.passes[0];
            let mut input = vec![0.0f32; m];
            input[..cols_here].copy_from_slice(&x[cp * m..cp * m + cols_here]);
            let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
            for (yo, pv) in out[rp * m..rp * m + rows_here].iter_mut().zip(&cols) {
                *yo += pv;
            }
        }
        out
    }

    fn recompute_op_monarch(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let op = &self.mapping.ops[op_idx];
        let d = self.b * self.b;
        assert_eq!(x.len(), op.cols, "monarch op input length");
        let perm = StridePerm::new(self.b);
        let (tr, tc) = (op.rows.div_ceil(d), op.cols.div_ceil(d));
        let mut y = vec![0.0f32; op.rows];
        let mut xseg = vec![0.0f32; d];
        for j in 0..tc {
            let cw = d.min(op.cols - j * d);
            xseg[..cw].copy_from_slice(&x[j * d..j * d + cw]);
            xseg[cw..].fill(0.0);
            let u = perm.apply(&xseg);
            for i in 0..tr {
                let tile = i * tc + j;
                let v = self.stage_pass(op_idx, Some(tile), Factor::Right, &u, true);
                let w = perm.apply(&v);
                let z = self.stage_pass(op_idx, Some(tile), Factor::Left, &w, true);
                let part = perm.apply(&z);
                let rh = d.min(op.rows - i * d);
                for (yo, pv) in y[i * d..i * d + rh].iter_mut().zip(&part) {
                    *yo += pv;
                }
            }
        }
        y
    }

    /// Mean array utilization measured from the programmed cells.
    pub fn measured_utilization(&self) -> f64 {
        let total: f64 = self.crossbars.iter().map(|c| c.utilization()).sum();
        total / self.crossbars.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn check_strategy(strategy: Strategy, d: usize, m: usize) {
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(42);
        let b = cfg.monarch_b();
        let mon = MonarchMatrix::randn(b, &mut rng);
        let mut chip =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon), &params, strategy);
        let x = rng.normal_vec(d);
        let got = chip.run_op(0, &x);
        let want = mon.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "{strategy:?} d={d} m={m}: {g} vs {w}"
            );
        }
        // the compiled replay must equal the schedule-recompute path
        // bit for bit
        assert_eq!(got, chip.run_op_recompute(0, &x), "{strategy:?} plan drift");
    }

    #[test]
    fn sparse_map_computes_correct_mvm() {
        check_strategy(Strategy::SparseMap, 64, 32); // b=8, lanes=4
        check_strategy(Strategy::SparseMap, 64, 16); // b=8, lanes=2
        check_strategy(Strategy::SparseMap, 16, 16); // b=4, lanes=4
    }

    #[test]
    fn dense_map_computes_correct_mvm() {
        check_strategy(Strategy::DenseMap, 64, 32);
        check_strategy(Strategy::DenseMap, 64, 64); // lanes=8
        check_strategy(Strategy::DenseMap, 16, 16);
    }

    #[test]
    fn dense_map_multiple_ops_share_arrays_correctly() {
        // Two ops packed into the same arrays must still compute their own
        // results (lane isolation via row activation).
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(7);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let mut chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        for (oi, mon) in mons.iter().enumerate() {
            let got = chip.run_op(oi, &x);
            let want = mon.matvec(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "op {oi}");
            }
        }
    }

    #[test]
    fn all_rows_activation_corrupts_densemap() {
        // §III-C: naively activating all rows must NOT give the right
        // answer when an array stores multiple lanes.
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(9);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        let xp = StridePerm::new(b).apply(&x);
        let scheduled = chip.run_stage(0, Factor::Right, &xp);
        let naive = chip.run_stage_all_rows(0, Factor::Right, &xp);
        let diff: f32 = scheduled
            .iter()
            .zip(&naive)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            diff > 1e-3,
            "all-row activation should corrupt DenseMap results (diff {diff})"
        );
    }

    #[test]
    fn measured_utilization_matches_mapping_stats() {
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(5);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let measured = chip.measured_utilization();
            let predicted = chip.mapping.utilization();
            // randn factors have no exact zeros, so programmed-cell count
            // tracks placement cell accounting
            assert!(
                (measured - predicted).abs() < 0.05,
                "{strategy:?}: measured {measured} vs predicted {predicted}"
            );
        }
    }

    /// Random tile grid for a rows x cols weight (d = tile dim).
    fn rect_randn(rows: usize, cols: usize, d: usize, rng: &mut Pcg32) -> RectMonarch {
        let b = (d as f64).sqrt().round() as usize;
        let tiles = rows.div_ceil(d) * cols.div_ceil(d);
        RectMonarch {
            rows,
            cols,
            n: d,
            tiles: (0..tiles).map(|_| MonarchMatrix::randn(b, rng)).collect(),
        }
    }

    fn ffn_ops(d: usize, d_ff: usize) -> (ModelConfig, Vec<MatmulOp>) {
        let (cfg, mut ops) = single_op(d);
        ops[0].name = "dec0.ffn1".to_string();
        ops[0].rows = d_ff;
        ops.push(MatmulOp {
            name: "dec0.ffn2".to_string(),
            stage: Stage::Decoder,
            layer: 0,
            kind: OpKind::Para,
            rows: d,
            cols: d_ff,
            batch: 1,
        });
        (cfg, ops)
    }

    #[test]
    fn rect_ops_match_reference_all_strategies() {
        // ffn-shaped rectangular weights (row tiles + col tiles) computed
        // on-chip must match the RectMonarch reference for every mapping.
        let (d, d_ff) = (64usize, 256usize);
        let (cfg, ops) = ffn_ops(d, d_ff);
        let mut rng = Pcg32::new(21);
        let weights = vec![
            rect_randn(d_ff, d, d, &mut rng),
            rect_randn(d, d_ff, d, &mut rng),
        ];
        let mut params = CimParams::default();
        params.array_dim = 32;
        for strategy in Strategy::all() {
            let mut chip = FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for (oi, w) in weights.iter().enumerate() {
                let x = Pcg32::new(100 + oi as u64).normal_vec(w.cols);
                let got = chip.run_op(oi, &x);
                let want = w.matvec(&x);
                assert_eq!(got.len(), w.rows);
                for (g, wv) in got.iter().zip(&want) {
                    assert!(
                        (g - wv).abs() < 2e-3 * (1.0 + wv.abs()),
                        "{strategy:?} op {oi}: {g} vs {wv}"
                    );
                }
                // replay == recompute, bit for bit, on rectangular grids
                assert_eq!(got, chip.run_op_recompute(oi, &x), "{strategy:?} op {oi}");
            }
        }
    }

    #[test]
    fn monarch_chip_is_bit_identical_to_reference() {
        // SparseMap/DenseMap passes replay the factored reference's
        // f32 operations in the same order — outputs must be bit-equal,
        // which is what lets decode compare strategies exactly.
        let d = 64;
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = 256;
        let mut rng = Pcg32::new(33);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let x = rng.normal_vec(d);
        let want = mon.matvec(&x);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let got = chip.run_op(0, &x);
            assert_eq!(got, want, "{strategy:?} not bit-identical");
        }
    }

    /// Interleave per-lane vectors into a stride-B buffer.
    fn interleave(lanes: &[Vec<f32>]) -> Vec<f32> {
        let batch = lanes.len();
        let n = lanes[0].len();
        let mut out = vec![0.0f32; n * batch];
        for (l, x) in lanes.iter().enumerate() {
            for (i, &v) in x.iter().enumerate() {
                out[i * batch + l] = v;
            }
        }
        out
    }

    #[test]
    fn batched_replay_bit_identical_per_lane() {
        // run_op_batch_into lane l == run_op_into over lane l's vector,
        // bitwise, for rectangular grids under every strategy.
        let (d, d_ff) = (64usize, 256usize);
        let (cfg, ops) = ffn_ops(d, d_ff);
        let mut rng = Pcg32::new(55);
        let weights = vec![
            rect_randn(d_ff, d, d, &mut rng),
            rect_randn(d, d_ff, d, &mut rng),
        ];
        let mut params = CimParams::default();
        params.array_dim = 32;
        for strategy in Strategy::all() {
            let mut chip =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for (oi, wgt) in weights.iter().enumerate() {
                for batch in [2usize, 3, 8] {
                    let lanes: Vec<Vec<f32>> = (0..batch)
                        .map(|l| Pcg32::new(500 + (oi * 10 + l) as u64).normal_vec(wgt.cols))
                        .collect();
                    let ys = chip.run_op_batch(oi, batch, &interleave(&lanes));
                    for (l, x) in lanes.iter().enumerate() {
                        let want = chip.run_op(oi, x);
                        for i in 0..wgt.rows {
                            assert_eq!(
                                ys[i * batch + l].to_bits(),
                                want[i].to_bits(),
                                "{strategy:?} op {oi} batch {batch} lane {l} row {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn replay_modes_bit_identical_single_and_batched() {
        // Bit-block replay (the default) must match index-list replay
        // AND the schedule-recompute audit path bitwise, single-stream
        // and per interleaved lane, on rectangular grids under every
        // strategy.
        let (d, d_ff) = (64usize, 256usize);
        let (cfg, ops) = ffn_ops(d, d_ff);
        let mut rng = Pcg32::new(77);
        let weights = vec![
            rect_randn(d_ff, d, d, &mut rng),
            rect_randn(d, d_ff, d, &mut rng),
        ];
        let mut params = CimParams::default();
        params.array_dim = 32;
        for strategy in Strategy::all() {
            let mut chip =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            assert_eq!(chip.replay_mode(), ReplayMode::BitBlock);
            for (oi, wgt) in weights.iter().enumerate() {
                let x = Pcg32::new(700 + oi as u64).normal_vec(wgt.cols);
                chip.set_replay_mode(ReplayMode::BitBlock);
                let bits = chip.run_op(oi, &x);
                chip.set_replay_mode(ReplayMode::IndexList);
                let idx = chip.run_op(oi, &x);
                let audit = chip.run_op_recompute(oi, &x);
                for i in 0..wgt.rows {
                    assert_eq!(
                        bits[i].to_bits(),
                        idx[i].to_bits(),
                        "{strategy:?} op {oi} row {i}: bit-block vs index"
                    );
                    assert_eq!(
                        bits[i].to_bits(),
                        audit[i].to_bits(),
                        "{strategy:?} op {oi} row {i}: bit-block vs recompute"
                    );
                }
                for batch in [2usize, 5] {
                    let lanes: Vec<Vec<f32>> = (0..batch)
                        .map(|l| Pcg32::new(800 + (oi * 10 + l) as u64).normal_vec(wgt.cols))
                        .collect();
                    let xs = interleave(&lanes);
                    chip.set_replay_mode(ReplayMode::BitBlock);
                    let yb = chip.run_op_batch(oi, batch, &xs);
                    chip.set_replay_mode(ReplayMode::IndexList);
                    let yi = chip.run_op_batch(oi, batch, &xs);
                    for (k, (gb, gi)) in yb.iter().zip(&yi).enumerate() {
                        assert_eq!(
                            gb.to_bits(),
                            gi.to_bits(),
                            "{strategy:?} op {oi} batch {batch} slot {k}"
                        );
                    }
                }
            }
            chip.set_replay_mode(ReplayMode::BitBlock);
        }
    }

    #[test]
    fn batched_replay_handles_shrinking_and_growing_widths() {
        // ensure_batch keeps capacity; running B=8 then B=2 then B=8
        // again must not leak stale lanes between calls.
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(91);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let mut chip = FunctionalChip::program(
            &cfg,
            &ops,
            std::slice::from_ref(&mon),
            &params,
            Strategy::DenseMap,
        );
        let lanes8: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(64)).collect();
        let lanes2: Vec<Vec<f32>> = lanes8[..2].to_vec();
        let first = chip.run_op_batch(0, 8, &interleave(&lanes8));
        let two = chip.run_op_batch(0, 2, &interleave(&lanes2));
        for (l, x) in lanes2.iter().enumerate() {
            let want = chip.run_op(0, x);
            for i in 0..64 {
                assert_eq!(two[i * 2 + l], want[i], "lane {l} after shrink");
            }
        }
        assert_eq!(first, chip.run_op_batch(0, 8, &interleave(&lanes8)));
    }

    #[test]
    fn batch_of_one_equals_single_stream() {
        // The B=1 fast path must be byte-for-byte the run_op_into path.
        let (cfg, ops) = single_op(16);
        let mut params = CimParams::default();
        params.array_dim = 16;
        let mut rng = Pcg32::new(13);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let x = rng.normal_vec(16);
            assert_eq!(chip.run_op_batch(0, 1, &x), chip.run_op(0, &x));
        }
    }

    #[test]
    fn analog_ideal_mode_bit_identical_to_plain_path() {
        // AnalogMode::ideal() must be byte-for-byte the non-analog chip:
        // cells untouched, replay untouched, for every strategy.
        use crate::cim::AnalogMode;
        let (d, d_ff) = (64usize, 256usize);
        let (cfg, ops) = ffn_ops(d, d_ff);
        let mut rng = Pcg32::new(101);
        let weights = vec![
            rect_randn(d_ff, d, d, &mut rng),
            rect_randn(d, d_ff, d, &mut rng),
        ];
        let mut params = CimParams::default();
        params.array_dim = 32;
        for strategy in Strategy::all() {
            let mut plain =
                FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            let mut ideal = FunctionalChip::program_rect_analog(
                &cfg,
                &ops,
                &weights,
                &params,
                strategy,
                Some(&AnalogMode::ideal()),
            );
            assert!(plain.analog_mode().is_none());
            assert!(ideal.analog_mode().is_some());
            for (a, b) in plain.crossbars.iter().zip(&ideal.crossbars) {
                assert_eq!(a.cells, b.cells, "{strategy:?} cells corrupted");
            }
            for oi in 0..weights.len() {
                let x = Pcg32::new(900 + oi as u64).normal_vec(weights[oi].cols);
                let want = plain.run_op(oi, &x);
                let got = ideal.run_op(oi, &x);
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "{strategy:?} op {oi}");
                }
            }
        }
    }

    #[test]
    fn analog_same_seed_is_bitwise_deterministic() {
        // Two independently programmed chips under the same noisy mode
        // must corrupt to bitwise-identical cells and outputs; a
        // different seed must not.
        use crate::cim::{AnalogMode, PcmNoise};
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(103);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let mode = AnalogMode {
            noise: PcmNoise::default(),
            adc_bits: None,
            seed: 42,
        };
        let rects: Vec<RectMonarch> = vec![rect_of(&mon)];
        let program = |m: &AnalogMode| {
            FunctionalChip::program_rect_analog(
                &cfg,
                &ops,
                &rects,
                &params,
                Strategy::SparseMap,
                Some(m),
            )
        };
        let mut a = program(&mode);
        let mut b = program(&mode);
        for (xa, xb) in a.crossbars.iter().zip(&b.crossbars) {
            assert_eq!(xa.cells, xb.cells, "same seed must corrupt identically");
        }
        let x = rng.normal_vec(64);
        let (ya, yb) = (a.run_op(0, &x), b.run_op(0, &x));
        for (g, w) in ya.iter().zip(&yb) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        let other = AnalogMode { seed: 43, ..mode };
        let c = program(&other);
        assert!(
            a.crossbars
                .iter()
                .zip(&c.crossbars)
                .any(|(xa, xc)| xa.cells != xc.cells),
            "different seed should corrupt differently"
        );
    }

    #[test]
    fn adc_cap_quantizes_below_required_bits_only() {
        // SparseMap d=64 (b=8) converts 8-deep bitlines no matter how
        // many blocks a whole-lane pass drives -> required_bits = 3: a
        // 2-bit cap must perturb the output; a 3-bit cap sits exactly at
        // the exact-conversion resolution and an 8-bit cap clears it, so
        // both must stay bit-identical to exact conversion.
        use crate::cim::AnalogMode;
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(107);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let rects: Vec<RectMonarch> = vec![rect_of(&mon)];
        let x = rng.normal_vec(64);
        let run = |bits: Option<u32>| {
            let mode = AnalogMode {
                adc_bits: bits,
                ..AnalogMode::ideal()
            };
            let mut chip = FunctionalChip::program_rect_analog(
                &cfg,
                &ops,
                &rects,
                &params,
                Strategy::SparseMap,
                Some(&mode),
            );
            chip.run_op(0, &x)
        };
        let exact = run(None);
        for bits in [3, 8] {
            let full = run(Some(bits));
            for (g, w) in full.iter().zip(&exact) {
                assert_eq!(g.to_bits(), w.to_bits(), "{bits}b cap must be exact");
            }
        }
        let capped = run(Some(2));
        let diff: f32 = capped
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.0, "2b cap must quantize 8-deep bitlines");
        // quantization error stays bounded: the capped chip still
        // approximates the operator
        let want = mon.matvec(&x);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (g, w) in capped.iter().zip(&want) {
            num += ((g - w) as f64).powi(2);
            den += (*w as f64).powi(2);
        }
        assert!((num / den).sqrt() < 0.6, "2b SparseMap rel err unbounded");
    }

    #[test]
    fn analog_replay_modes_stay_bit_identical() {
        // The ADC hook sits after the mvm call in both encodings, so
        // bit-block vs index-list stay bit-identical under a biting cap
        // (2 bits < the 3 bits an 8-deep Monarch bitline needs) too.
        use crate::cim::{AnalogMode, PcmNoise};
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(109);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let rects: Vec<RectMonarch> = vec![rect_of(&mon)];
        let mode = AnalogMode {
            noise: PcmNoise::default(),
            adc_bits: Some(2),
            seed: 7,
        };
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut chip = FunctionalChip::program_rect_analog(
                &cfg,
                &ops,
                &rects,
                &params,
                strategy,
                Some(&mode),
            );
            let x = rng.normal_vec(64);
            chip.set_replay_mode(ReplayMode::BitBlock);
            let bb = chip.run_op(0, &x);
            chip.set_replay_mode(ReplayMode::IndexList);
            let il = chip.run_op(0, &x);
            for (a, b) in bb.iter().zip(&il) {
                assert_eq!(a.to_bits(), b.to_bits(), "{strategy:?} encoding drift");
            }
        }
    }

    #[test]
    fn replay_reuses_scratch_across_calls() {
        // Back-to-back run_op calls must be independent (stale scratch
        // contents never leak into the next token's result).
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(77);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let x1 = rng.normal_vec(64);
            let x2 = rng.normal_vec(64);
            let first = chip.run_op(0, &x1);
            let _ = chip.run_op(0, &x2); // dirty the scratch
            assert_eq!(first, chip.run_op(0, &x1), "{strategy:?} scratch leak");
        }
    }
}
