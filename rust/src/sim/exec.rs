//! Functional execution of mapped Monarch operators on emulated
//! crossbars — the correctness half of the simulator.
//!
//! This module demonstrates, numerically, that the mapping strategies and
//! the scheduler's row-activation/rotation handling compute the *right
//! answer*: programming the factor blocks at their placement coordinates,
//! driving only the scheduled rows, de-rotating lane outputs by the
//! diagonal index, and applying the stride permutation between stages
//! reproduces `MonarchMatrix::matvec` exactly. It also exhibits the
//! §III-C failure mode: activating all rows of a DenseMap array mixes
//! lanes and corrupts the result.

use crate::cim::crossbar::Crossbar;
use crate::cim::CimParams;
use crate::mapping::rotation::rotate_blocks_left;
use crate::mapping::{map_ops, Factor, ModelMapping};
use crate::mapping::Strategy;
use crate::model::{MatmulOp, ModelConfig, OpKind, Stage};
use crate::monarch::{MonarchMatrix, StridePerm};

/// A programmed chip: one crossbar per allocated array.
pub struct FunctionalChip {
    pub m: usize,
    pub b: usize,
    pub crossbars: Vec<Crossbar>,
    pub mapping: ModelMapping,
}

/// Build a single-op model config/op-list for a d x d Monarch weight.
pub fn single_op(d: usize) -> (ModelConfig, Vec<MatmulOp>) {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = d;
    let op = MatmulOp {
        name: "dec0.wq".to_string(),
        stage: Stage::Decoder,
        layer: 0,
        kind: OpKind::Para,
        rows: d,
        cols: d,
        batch: 1,
    };
    (cfg, vec![op])
}

impl FunctionalChip {
    /// Program the factors of `ops[i] -> monarchs[i]` according to the
    /// mapping's placements.
    pub fn program(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        monarchs: &[MonarchMatrix],
        params: &CimParams,
        strategy: Strategy,
    ) -> FunctionalChip {
        assert!(matches!(strategy, Strategy::SparseMap | Strategy::DenseMap));
        let mapping = map_ops(cfg, ops, params, strategy);
        let m = params.array_dim;
        let b = cfg.monarch_b();
        let mut crossbars: Vec<Crossbar> =
            (0..mapping.arrays).map(|_| Crossbar::new(m)).collect();
        for p in &mapping.placements {
            let mon = &monarchs[p.op];
            let factor_bd = match p.factor {
                Factor::Left => &mon.l,
                Factor::Right => &mon.r,
                Factor::Dense => unreachable!("functional sim is Monarch-only"),
            };
            let lanes = (m / b).max(1);
            for j in 0..p.blocks {
                // global block index within the factor
                let gblk = p.lane_of_factor * lanes + j;
                // Program the TRANSPOSE: bitline accumulation computes
                // cells^T @ input, so storing B^T yields y = B x.
                let blk = factor_bd.block_matrix(gblk).transpose();
                let (r0, c0) = (j * b, ((j + p.diag) % lanes) * b);
                crossbars[p.array].program_block(r0, c0, &blk);
            }
        }
        FunctionalChip {
            m,
            b,
            crossbars,
            mapping,
        }
    }

    fn stage_pass(
        &self,
        op_idx: usize,
        factor: Factor,
        x: &[f32],
        honor_schedule: bool,
    ) -> Vec<f32> {
        let b = self.b;
        let lanes = (self.m / b).max(1);
        let n = x.len();
        let dense = self.mapping.strategy == Strategy::DenseMap;
        let mut out = vec![0.0f32; n];
        for p in self
            .mapping
            .placements
            .iter()
            .filter(|p| p.op == op_idx && p.factor == factor)
        {
            // Input segment for this lane: blocks [chunk*lanes, ...)
            let base = p.lane_of_factor * lanes;
            if dense && honor_schedule {
                // DenseMap (§III-C): arrays hold several lanes whose
                // cells share columns, so the scheduler walks block-row
                // groups — activate rows of block j only, convert only
                // the lane's column block (j + diag) % lanes. The analog
                // passes pipeline behind the ADC stream (sample-and-
                // hold), which is what `scheduler::timing` models.
                for j in 0..p.blocks {
                    let src = (base + j) * b;
                    let mut input = vec![0.0f32; self.m];
                    input[j * b..(j + 1) * b].copy_from_slice(&x[src..src + b]);
                    let rows: Vec<usize> = (j * b..(j + 1) * b).collect();
                    let cols = self.crossbars[p.array].mvm_pass(&input, &rows);
                    let cblk = ((j + p.diag) % lanes) * b;
                    out[src..src + b].copy_from_slice(&cols[cblk..cblk + b]);
                }
            } else {
                // Whole-lane pass: correct for SparseMap (one lane per
                // array, disjoint rows AND columns); the §III-C naive
                // failure mode for DenseMap (mixes co-resident lanes).
                let mut input = vec![0.0f32; self.m];
                let mut rows = Vec::new();
                for j in 0..p.blocks {
                    let src = (base + j) * b;
                    input[j * b..(j + 1) * b].copy_from_slice(&x[src..src + b]);
                    rows.extend(j * b..(j + 1) * b);
                }
                let cols = self.crossbars[p.array].mvm_pass(&input, &rows);
                // Block j's output sits at column block (j + diag) %
                // lanes; de-rotate to logical order.
                let aligned = rotate_blocks_left(&cols, b, p.diag);
                for j in 0..p.blocks {
                    let dst = (base + j) * b;
                    out[dst..dst + b].copy_from_slice(&aligned[j * b..(j + 1) * b]);
                }
            }
        }
        out
    }

    /// Execute one factor stage with the scheduler's row activation.
    pub fn run_stage(&self, op_idx: usize, factor: Factor, x: &[f32]) -> Vec<f32> {
        self.stage_pass(op_idx, factor, x, true)
    }

    /// §III-C negative model: drive ALL rows (ignore the schedule).
    pub fn run_stage_all_rows(
        &self,
        op_idx: usize,
        factor: Factor,
        x: &[f32],
    ) -> Vec<f32> {
        self.stage_pass(op_idx, factor, x, false)
    }

    /// Full Monarch MVM for op `op_idx`: P, R stage, P, L stage, P.
    pub fn run_op(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let p = StridePerm::new(self.b);
        let u = p.apply(x);
        let v = self.run_stage(op_idx, Factor::Right, &u);
        let w = p.apply(&v);
        let z = self.run_stage(op_idx, Factor::Left, &w);
        p.apply(&z)
    }

    /// Mean array utilization measured from the programmed cells.
    pub fn measured_utilization(&self) -> f64 {
        let total: f64 = self.crossbars.iter().map(|c| c.utilization()).sum();
        total / self.crossbars.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn check_strategy(strategy: Strategy, d: usize, m: usize) {
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(42);
        let b = cfg.monarch_b();
        let mon = MonarchMatrix::randn(b, &mut rng);
        let chip =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon), &params, strategy);
        let x = rng.normal_vec(d);
        let got = chip.run_op(0, &x);
        let want = mon.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "{strategy:?} d={d} m={m}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn sparse_map_computes_correct_mvm() {
        check_strategy(Strategy::SparseMap, 64, 32); // b=8, lanes=4
        check_strategy(Strategy::SparseMap, 64, 16); // b=8, lanes=2
        check_strategy(Strategy::SparseMap, 16, 16); // b=4, lanes=4
    }

    #[test]
    fn dense_map_computes_correct_mvm() {
        check_strategy(Strategy::DenseMap, 64, 32);
        check_strategy(Strategy::DenseMap, 64, 64); // lanes=8
        check_strategy(Strategy::DenseMap, 16, 16);
    }

    #[test]
    fn dense_map_multiple_ops_share_arrays_correctly() {
        // Two ops packed into the same arrays must still compute their own
        // results (lane isolation via row activation).
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(7);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        for (oi, mon) in mons.iter().enumerate() {
            let got = chip.run_op(oi, &x);
            let want = mon.matvec(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "op {oi}");
            }
        }
    }

    #[test]
    fn all_rows_activation_corrupts_densemap() {
        // §III-C: naively activating all rows must NOT give the right
        // answer when an array stores multiple lanes.
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(9);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        let xp = StridePerm::new(b).apply(&x);
        let scheduled = chip.run_stage(0, Factor::Right, &xp);
        let naive = chip.run_stage_all_rows(0, Factor::Right, &xp);
        let diff: f32 = scheduled
            .iter()
            .zip(&naive)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            diff > 1e-3,
            "all-row activation should corrupt DenseMap results (diff {diff})"
        );
    }

    #[test]
    fn measured_utilization_matches_mapping_stats() {
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(5);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let measured = chip.measured_utilization();
            let predicted = chip.mapping.utilization();
            // randn factors have no exact zeros, so programmed-cell count
            // tracks placement cell accounting
            assert!(
                (measured - predicted).abs() < 0.05,
                "{strategy:?}: measured {measured} vs predicted {predicted}"
            );
        }
    }
}
