//! Functional execution of mapped operators on emulated crossbars — the
//! correctness half of the simulator.
//!
//! This module demonstrates, numerically, that the mapping strategies and
//! the scheduler's row-activation/rotation handling compute the *right
//! answer*: programming the factor blocks at their placement coordinates,
//! driving only the scheduled rows ([`crate::scheduler::placement_schedule`]
//! supplies every activation mask), de-rotating lane outputs by the
//! diagonal index, and applying the stride permutation between stages
//! reproduces `MonarchMatrix::matvec` exactly. It also exhibits the
//! §III-C failure mode: activating all rows of a DenseMap array mixes
//! lanes and corrupts the result.
//!
//! Beyond the original single-op checker, the chip now executes *whole
//! models*: rectangular weights as tile grids of Monarch operators
//! ([`RectMonarch`], mirroring `mapping`'s d x d partition) and the
//! Linear baseline (dense tiles, partial-sum accumulation over column
//! partitions) — the substrate of the autoregressive decode engine
//! (`sim::decode`).

use crate::cim::crossbar::Crossbar;
use crate::cim::CimParams;
use crate::mapping::rotation::rotate_blocks_left;
use crate::mapping::{map_ops, Factor, MappedOp, ModelMapping};
use crate::mapping::Strategy;
use crate::model::{MatmulOp, ModelConfig, OpKind, Stage};
use crate::monarch::{MonarchMatrix, RectMonarch, StridePerm};
use crate::scheduler::placement_schedule;
use crate::tensor::Matrix;

/// A programmed chip: one crossbar per allocated array.
pub struct FunctionalChip {
    pub m: usize,
    pub b: usize,
    pub crossbars: Vec<Crossbar>,
    pub mapping: ModelMapping,
    /// Placement indices grouped per op (insertion order preserved), so
    /// per-token execution doesn't rescan the whole model's placements
    /// for every stage of every tile.
    op_placements: Vec<Vec<usize>>,
}

/// Build a single-op model config/op-list for a d x d Monarch weight.
pub fn single_op(d: usize) -> (ModelConfig, Vec<MatmulOp>) {
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = d;
    let op = MatmulOp {
        name: "dec0.wq".to_string(),
        stage: Stage::Decoder,
        layer: 0,
        kind: OpKind::Para,
        rows: d,
        cols: d,
        batch: 1,
    };
    (cfg, vec![op])
}

/// Geometry of one Linear placement's m x m tile: `(rp, cp, rows_here,
/// cols_here)`. Single source of the `tile == rp * col_parts + cp`
/// convention `mapping::linear` allocates with — used for both
/// programming and execution so the two can't drift apart.
fn linear_tile_geometry(op: &MappedOp, tile: usize, m: usize) -> (usize, usize, usize, usize) {
    let col_parts = op.cols.div_ceil(m);
    let (rp, cp) = (tile / col_parts, tile % col_parts);
    (rp, cp, m.min(op.rows - rp * m), m.min(op.cols - cp * m))
}

/// Wrap a square single-tile Monarch as a 1x1 [`RectMonarch`] grid.
fn rect_of(mon: &MonarchMatrix) -> RectMonarch {
    RectMonarch {
        rows: mon.n(),
        cols: mon.n(),
        n: mon.n(),
        tiles: vec![mon.clone()],
    }
}

impl FunctionalChip {
    /// Program the factors of `ops[i] -> monarchs[i]` (square d x d ops)
    /// according to the mapping's placements. Monarch strategies only;
    /// for Linear or rectangular weights use [`FunctionalChip::program_rect`].
    pub fn program(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        monarchs: &[MonarchMatrix],
        params: &CimParams,
        strategy: Strategy,
    ) -> FunctionalChip {
        assert!(matches!(strategy, Strategy::SparseMap | Strategy::DenseMap));
        let rects: Vec<RectMonarch> = monarchs.iter().map(rect_of).collect();
        Self::program_rect(cfg, ops, &rects, params, strategy)
    }

    /// Program a whole op list whose weights are tile grids of Monarch
    /// operators, under any of the three mapping strategies.
    ///
    /// * SparseMap/DenseMap: each placement's factor blocks are taken
    ///   from `weights[op].tiles[tile]` and programmed **transposed** at
    ///   their placement coordinates (bitline accumulation computes
    ///   `cells^T @ input`, so storing `B^T` yields `y = B x`).
    /// * Linear: the dense materialization of each weight is cut into
    ///   m x m tiles and programmed transposed, one tile per array — the
    ///   paper's baseline of running the *same* operator un-factored.
    pub fn program_rect(
        cfg: &ModelConfig,
        ops: &[MatmulOp],
        weights: &[RectMonarch],
        params: &CimParams,
        strategy: Strategy,
    ) -> FunctionalChip {
        assert_eq!(ops.len(), weights.len(), "one weight grid per op");
        for (op, w) in ops.iter().zip(weights) {
            assert_eq!(
                (op.rows, op.cols),
                (w.rows, w.cols),
                "weight shape mismatch for op {}",
                op.name
            );
        }
        let mapping = map_ops(cfg, ops, params, strategy);
        let m = params.array_dim;
        let b = cfg.monarch_b();
        let mut crossbars: Vec<Crossbar> =
            (0..mapping.arrays).map(|_| Crossbar::new(m)).collect();
        if strategy == Strategy::Linear {
            let denses: Vec<Matrix> = weights.iter().map(|w| w.to_dense()).collect();
            for p in &mapping.placements {
                let op = &mapping.ops[p.op];
                let (rp, cp, rows_here, cols_here) = linear_tile_geometry(op, p.tile, m);
                let tile = denses[p.op].submatrix(rp * m, cp * m, rows_here, cols_here);
                crossbars[p.array].program_block(0, 0, &tile.transpose());
            }
        } else {
            for p in &mapping.placements {
                let rect = &weights[p.op];
                assert_eq!(rect.n, b * b, "tile dim must match d_model");
                let mon = &rect.tiles[p.tile];
                let factor_bd = match p.factor {
                    Factor::Left => &mon.l,
                    Factor::Right => &mon.r,
                    Factor::Dense => unreachable!("dense placement in Monarch mapping"),
                };
                let lanes = (m / b).max(1);
                for j in 0..p.blocks {
                    // global block index within the factor
                    let gblk = p.lane_of_factor * lanes + j;
                    let blk = factor_bd.block_matrix(gblk).transpose();
                    let (r0, c0) = (j * b, ((j + p.diag) % lanes) * b);
                    crossbars[p.array].program_block(r0, c0, &blk);
                }
            }
        }
        let mut op_placements: Vec<Vec<usize>> = vec![Vec::new(); mapping.ops.len()];
        for (i, p) in mapping.placements.iter().enumerate() {
            op_placements[p.op].push(i);
        }
        FunctionalChip {
            m,
            b,
            crossbars,
            mapping,
            op_placements,
        }
    }

    /// Execute one Monarch factor stage of one op. `tile = None` spans
    /// every tile's placements (the original single-tile behaviour);
    /// `Some(t)` restricts to one d x d tile of a rectangular weight.
    /// Row activation, column selection and output rotation all come
    /// from the scheduler's [`placement_schedule`].
    fn stage_pass(
        &self,
        op_idx: usize,
        tile: Option<usize>,
        factor: Factor,
        x: &[f32],
        honor_schedule: bool,
    ) -> Vec<f32> {
        let b = self.b;
        let lanes = (self.m / b).max(1);
        let n = x.len();
        let dense = self.mapping.strategy == Strategy::DenseMap;
        let walk = dense && honor_schedule;
        let mut out = vec![0.0f32; n];
        for p in self.op_placements[op_idx]
            .iter()
            .map(|&i| &self.mapping.placements[i])
            .filter(|p| p.factor == factor && tile.map_or(true, |t| p.tile == t))
        {
            // Input segment for this lane: blocks [chunk*lanes, ...)
            let base = p.lane_of_factor * lanes;
            let sched = placement_schedule(p, self.m, walk);
            if walk {
                // DenseMap (§III-C): arrays hold several lanes whose
                // cells share columns, so the scheduler walks block-row
                // groups — one pass per block, converting only the
                // lane's own column group. The analog passes pipeline
                // behind the ADC stream (sample-and-hold), which is what
                // `scheduler::timing` models.
                for (j, pass) in sched.passes.iter().enumerate() {
                    let src = (base + j) * b;
                    let mut input = vec![0.0f32; self.m];
                    for (k, &r) in pass.rows.iter().enumerate() {
                        input[r] = x[src + k];
                    }
                    let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
                    for (k, &c) in pass.cols.iter().enumerate() {
                        out[src + k] = cols[c];
                    }
                }
            } else {
                // Whole-lane pass: correct for SparseMap (one lane per
                // array, disjoint rows AND columns); the §III-C naive
                // failure mode for DenseMap (mixes co-resident lanes).
                let pass = &sched.passes[0];
                let mut input = vec![0.0f32; self.m];
                for (k, &r) in pass.rows.iter().enumerate() {
                    input[r] = x[base * b + k];
                }
                let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
                // Block j's output sits at column block (j + diag) %
                // lanes; de-rotate to logical order per the Route command.
                let aligned = rotate_blocks_left(&cols, b, sched.rotation);
                for j in 0..p.blocks {
                    let dst = (base + j) * b;
                    out[dst..dst + b].copy_from_slice(&aligned[j * b..(j + 1) * b]);
                }
            }
        }
        out
    }

    /// Execute one factor stage with the scheduler's row activation.
    pub fn run_stage(&self, op_idx: usize, factor: Factor, x: &[f32]) -> Vec<f32> {
        self.stage_pass(op_idx, None, factor, x, true)
    }

    /// §III-C negative model: drive ALL rows (ignore the schedule).
    pub fn run_stage_all_rows(
        &self,
        op_idx: usize,
        factor: Factor,
        x: &[f32],
    ) -> Vec<f32> {
        self.stage_pass(op_idx, None, factor, x, false)
    }

    /// Full MVM for op `op_idx`: `y = W x` with `x.len() == op.cols` and
    /// `y.len() == op.rows`. Monarch strategies run P, R, P, L, P per
    /// d x d tile with row-tile accumulation (mirroring
    /// `RectMonarch::matvec` exactly, so results are bit-comparable);
    /// Linear runs dense tile passes with column-partition partial sums.
    pub fn run_op(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        match self.mapping.strategy {
            Strategy::Linear => self.run_op_linear(op_idx, x),
            _ => self.run_op_monarch(op_idx, x),
        }
    }

    fn run_op_linear(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let m = self.m;
        let op = &self.mapping.ops[op_idx];
        assert_eq!(x.len(), op.cols, "linear op input length");
        let mut out = vec![0.0f32; op.rows];
        // Placements were allocated row-partition-major with ascending
        // column partitions, so iterating in order fixes the partial-sum
        // accumulation order (shift-add tree determinism).
        for p in self.op_placements[op_idx]
            .iter()
            .map(|&i| &self.mapping.placements[i])
        {
            let (rp, cp, rows_here, cols_here) = linear_tile_geometry(op, p.tile, m);
            let sched = placement_schedule(p, m, false);
            let pass = &sched.passes[0];
            let mut input = vec![0.0f32; m];
            input[..cols_here].copy_from_slice(&x[cp * m..cp * m + cols_here]);
            let cols = self.crossbars[p.array].mvm_pass(&input, &pass.rows);
            for (yo, pv) in out[rp * m..rp * m + rows_here].iter_mut().zip(&cols) {
                *yo += pv;
            }
        }
        out
    }

    fn run_op_monarch(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        let op = &self.mapping.ops[op_idx];
        let d = self.b * self.b;
        assert_eq!(x.len(), op.cols, "monarch op input length");
        let perm = StridePerm::new(self.b);
        let (tr, tc) = (op.rows.div_ceil(d), op.cols.div_ceil(d));
        let mut y = vec![0.0f32; op.rows];
        let mut xseg = vec![0.0f32; d];
        for j in 0..tc {
            // zero-padded input segment (same loop structure as
            // RectMonarch::matvec for bit-identical accumulation order)
            let cw = d.min(op.cols - j * d);
            xseg[..cw].copy_from_slice(&x[j * d..j * d + cw]);
            xseg[cw..].iter_mut().for_each(|v| *v = 0.0);
            let u = perm.apply(&xseg);
            for i in 0..tr {
                let tile = i * tc + j;
                let v = self.stage_pass(op_idx, Some(tile), Factor::Right, &u, true);
                let w = perm.apply(&v);
                let z = self.stage_pass(op_idx, Some(tile), Factor::Left, &w, true);
                let part = perm.apply(&z);
                let rh = d.min(op.rows - i * d);
                for (yo, pv) in y[i * d..i * d + rh].iter_mut().zip(&part) {
                    *yo += pv;
                }
            }
        }
        y
    }

    /// Mean array utilization measured from the programmed cells.
    pub fn measured_utilization(&self) -> f64 {
        let total: f64 = self.crossbars.iter().map(|c| c.utilization()).sum();
        total / self.crossbars.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn check_strategy(strategy: Strategy, d: usize, m: usize) {
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = m;
        let mut rng = Pcg32::new(42);
        let b = cfg.monarch_b();
        let mon = MonarchMatrix::randn(b, &mut rng);
        let chip =
            FunctionalChip::program(&cfg, &ops, std::slice::from_ref(&mon), &params, strategy);
        let x = rng.normal_vec(d);
        let got = chip.run_op(0, &x);
        let want = mon.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "{strategy:?} d={d} m={m}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn sparse_map_computes_correct_mvm() {
        check_strategy(Strategy::SparseMap, 64, 32); // b=8, lanes=4
        check_strategy(Strategy::SparseMap, 64, 16); // b=8, lanes=2
        check_strategy(Strategy::SparseMap, 16, 16); // b=4, lanes=4
    }

    #[test]
    fn dense_map_computes_correct_mvm() {
        check_strategy(Strategy::DenseMap, 64, 32);
        check_strategy(Strategy::DenseMap, 64, 64); // lanes=8
        check_strategy(Strategy::DenseMap, 16, 16);
    }

    #[test]
    fn dense_map_multiple_ops_share_arrays_correctly() {
        // Two ops packed into the same arrays must still compute their own
        // results (lane isolation via row activation).
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(7);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        for (oi, mon) in mons.iter().enumerate() {
            let got = chip.run_op(oi, &x);
            let want = mon.matvec(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "op {oi}");
            }
        }
    }

    #[test]
    fn all_rows_activation_corrupts_densemap() {
        // §III-C: naively activating all rows must NOT give the right
        // answer when an array stores multiple lanes.
        let d = 64;
        let (cfg, op0) = single_op(d);
        let mut ops = op0.clone();
        let mut op1 = op0[0].clone();
        op1.name = "dec0.wk".to_string();
        ops.push(op1);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(9);
        let b = cfg.monarch_b();
        let mons = vec![
            MonarchMatrix::randn(b, &mut rng),
            MonarchMatrix::randn(b, &mut rng),
        ];
        let chip = FunctionalChip::program(&cfg, &ops, &mons, &params, Strategy::DenseMap);
        let x = rng.normal_vec(d);
        let xp = StridePerm::new(b).apply(&x);
        let scheduled = chip.run_stage(0, Factor::Right, &xp);
        let naive = chip.run_stage_all_rows(0, Factor::Right, &xp);
        let diff: f32 = scheduled
            .iter()
            .zip(&naive)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            diff > 1e-3,
            "all-row activation should corrupt DenseMap results (diff {diff})"
        );
    }

    #[test]
    fn measured_utilization_matches_mapping_stats() {
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(5);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let measured = chip.measured_utilization();
            let predicted = chip.mapping.utilization();
            // randn factors have no exact zeros, so programmed-cell count
            // tracks placement cell accounting
            assert!(
                (measured - predicted).abs() < 0.05,
                "{strategy:?}: measured {measured} vs predicted {predicted}"
            );
        }
    }

    /// Random tile grid for a rows x cols weight (d = tile dim).
    fn rect_randn(rows: usize, cols: usize, d: usize, rng: &mut Pcg32) -> RectMonarch {
        let b = (d as f64).sqrt().round() as usize;
        let tiles = rows.div_ceil(d) * cols.div_ceil(d);
        RectMonarch {
            rows,
            cols,
            n: d,
            tiles: (0..tiles).map(|_| MonarchMatrix::randn(b, rng)).collect(),
        }
    }

    fn ffn_ops(d: usize, d_ff: usize) -> (ModelConfig, Vec<MatmulOp>) {
        let (cfg, mut ops) = single_op(d);
        ops[0].name = "dec0.ffn1".to_string();
        ops[0].rows = d_ff;
        ops.push(MatmulOp {
            name: "dec0.ffn2".to_string(),
            stage: Stage::Decoder,
            layer: 0,
            kind: OpKind::Para,
            rows: d,
            cols: d_ff,
            batch: 1,
        });
        (cfg, ops)
    }

    #[test]
    fn rect_ops_match_reference_all_strategies() {
        // ffn-shaped rectangular weights (row tiles + col tiles) computed
        // on-chip must match the RectMonarch reference for every mapping.
        let (d, d_ff) = (64usize, 256usize);
        let (cfg, ops) = ffn_ops(d, d_ff);
        let mut rng = Pcg32::new(21);
        let weights = vec![
            rect_randn(d_ff, d, d, &mut rng),
            rect_randn(d, d_ff, d, &mut rng),
        ];
        let mut params = CimParams::default();
        params.array_dim = 32;
        for strategy in Strategy::all() {
            let chip = FunctionalChip::program_rect(&cfg, &ops, &weights, &params, strategy);
            for (oi, w) in weights.iter().enumerate() {
                let x = Pcg32::new(100 + oi as u64).normal_vec(w.cols);
                let got = chip.run_op(oi, &x);
                let want = w.matvec(&x);
                assert_eq!(got.len(), w.rows);
                for (g, wv) in got.iter().zip(&want) {
                    assert!(
                        (g - wv).abs() < 2e-3 * (1.0 + wv.abs()),
                        "{strategy:?} op {oi}: {g} vs {wv}"
                    );
                }
            }
        }
    }

    #[test]
    fn monarch_chip_is_bit_identical_to_reference() {
        // SparseMap/DenseMap passes replay the factored reference's
        // f32 operations in the same order — outputs must be bit-equal,
        // which is what lets decode compare strategies exactly.
        let d = 64;
        let (cfg, ops) = single_op(d);
        let mut params = CimParams::default();
        params.array_dim = 256;
        let mut rng = Pcg32::new(33);
        let mon = MonarchMatrix::randn(cfg.monarch_b(), &mut rng);
        let x = rng.normal_vec(d);
        let want = mon.matvec(&x);
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let chip = FunctionalChip::program(
                &cfg,
                &ops,
                std::slice::from_ref(&mon),
                &params,
                strategy,
            );
            let got = chip.run_op(0, &x);
            assert_eq!(got, want, "{strategy:?} not bit-identical");
        }
    }
}
