//! Speculative decoding on the chunk engine (DESIGN.md §6d): a cheap
//! draft model proposes K tokens per round, and the target chip
//! verifies all K+1 positions — the pending token plus every proposal —
//! through ONE `step_chunks`-style batched replay with **lanes =
//! positions** (`sim::prefill`).
//!
//! Why this works on CIM: decode is memory-bound because every token
//! drives one activation vector through arrays holding the whole model
//! (PAPER.md §I, §III-C). The weights are resident, so a verify chunk
//! rides the same pass tables chunked prefill built — each programmed
//! cell is read once per pass and updates K+1 accumulators — turning K
//! sequential decode steps into a single pipelined replay. What
//! speculation buys is *latency* (one pass instead of K+1); what it
//! risks is *energy* (rejected lanes drove rows and converted columns
//! for nothing). Both sides are accounted honestly
//! (`trace::speculative_round_cost`).
//!
//! The acceptance rule is **greedy**: a proposal survives only if it
//! equals the target's own argmax at that position. Combined with the
//! per-lane bit-identicality of the batched replay
//! (`tests/prop_prefill.rs`) and exact KV rollback past the first
//! rejection ([`KvCache::truncate`]), the emitted token sequence is
//! **guaranteed bit-identical** to [`DecodeEngine::generate`] for every
//! model, mapping strategy, K and draft — a bad draft can only cost
//! rounds, never change the output (`tests/prop_speculative.rs`).
//!
//! Round protocol (the `pending` token is the newest emitted token, not
//! yet in the target cache):
//!
//! 1. the draft catches up to the emitted stream, then greedily
//!    proposes `d_1..d_K` (feeding its own proposals);
//! 2. the target verifies the chunk `[pending, d_1, .., d_K]` in one
//!    batched replay — lane `j`'s argmax is the target's true token
//!    after `chunk[..=j]`;
//! 3. lane 0's argmax is always emitted (it only depends on `pending`);
//!    each further lane counts only while the proposals keep matching
//!    the emitted tokens — `a` accepted proposals emit `a + 1` tokens;
//! 4. rollback: the target cache keeps `pending` and the `a` accepted
//!    proposals and truncates the rejected tail; the draft truncates to
//!    its longest prefix of the emitted stream.
//!
//! A layer-truncated **self-draft** ([`self_draft_model`]) reuses the
//! target's own weight stream: `DecodeModel::synth` seeds weights per
//! op index and the op list is layer-major, so a config with fewer
//! decoder layers synthesizes bitwise the target's first layers (and
//! the same embeddings/LM head). Full depth makes a perfect draft —
//! every round accepts all K proposals — which pins the best case in
//! the bench sweep (`BENCH_spec.json`).

use crate::cim::{CimParams, Cost};
use crate::mapping::Strategy;
use crate::model::ModelConfig;
use crate::sim::decode::{
    argmax, assert_fits_context, BatchDecodeEngine, DecodeEngine, DecodeModel,
};
use crate::sim::prefill::KvCache;
use crate::sim::trace::{speculative_round_cost, sum_costs, SpeculativeRoundCost};

/// Layer-truncated self-draft of a target config: the first `layers`
/// decoder layers of the target's own weight stream. Synthesis is
/// seeded per op index over a layer-major op list, so with the same
/// `seed` the truncated model's weights (and embeddings, positional
/// table and LM head) are bitwise the target's. `layers == 0` (the
/// CLI/server default) means full depth — a *perfect* draft; smaller
/// `layers` trade acceptance for draft cost (deeper requests are
/// capped at the target's depth).
pub fn self_draft_model(cfg: &ModelConfig, seed: u64, layers: usize) -> DecodeModel {
    let mut dcfg = cfg.clone();
    dcfg.dec_layers = self_draft_layers(cfg, layers);
    DecodeModel::synth(dcfg, seed)
}

/// Effective depth of a self-draft request against a target config:
/// `0` means full depth, deeper requests cap at the target's layer
/// count — the single source of the CLI/server `--draft-layers`
/// convention (no caller re-derives it).
pub fn self_draft_layers(cfg: &ModelConfig, layers: usize) -> usize {
    if layers == 0 {
        cfg.dec_layers
    } else {
        layers.min(cfg.dec_layers)
    }
}

/// One speculative round's outcome and bill.
#[derive(Clone, Debug)]
pub struct SpecRound {
    /// Target KV length when the verify chunk entered.
    pub base_kv: usize,
    /// Positions fed through the verify replay (1 pending + proposals).
    pub lanes: usize,
    /// Draft tokens proposed this round (`lanes - 1`).
    pub proposed: usize,
    /// Proposals accepted (each equal to the target's own argmax); the
    /// round emitted `accepted + 1` tokens.
    pub accepted: usize,
    /// Modeled cost of the verify replay — every lane pays, rejected or
    /// not; latency is the single pipelined pass.
    pub verify: SpeculativeRoundCost,
    /// Summed modeled cost of the draft forwards this round (catch-up +
    /// proposal feeding; zero for a reference-backend draft).
    pub draft_cost: Cost,
}

/// Result of one speculative generation run.
#[derive(Clone, Debug)]
pub struct SpeculativeResult {
    /// The generated token ids (prompt excluded) — bit-identical to
    /// [`DecodeEngine::generate`] on the same model.
    pub tokens: Vec<i32>,
    /// Per-round records, in round order.
    pub rounds: Vec<SpecRound>,
    /// Cost of every position fed through the target chip, in fed
    /// order: prompt prefill first, then every verify lane of every
    /// round — **rejected lanes included** (they drove rows and
    /// converted columns like any accepted lane).
    pub per_position: Vec<Cost>,
    /// Modeled cost of the draft's prompt ingestion (each round carries
    /// its own draft share in [`SpecRound::draft_cost`]).
    pub draft_prefill: Cost,
}

impl SpeculativeResult {
    /// Draft tokens proposed across all rounds.
    pub fn total_proposed(&self) -> usize {
        self.rounds.iter().map(|r| r.proposed).sum()
    }

    /// Draft tokens accepted across all rounds.
    pub fn total_accepted(&self) -> usize {
        self.rounds.iter().map(|r| r.accepted).sum()
    }

    /// Accepted / proposed over the whole run (0 when nothing was
    /// proposed — e.g. K effectively 0 near the tail).
    pub fn acceptance_rate(&self) -> f64 {
        let p = self.total_proposed();
        if p == 0 {
            0.0
        } else {
            self.total_accepted() as f64 / p as f64
        }
    }

    /// Mean tokens emitted per verify round (the first generated token
    /// comes from the prefill logits, not a round, so it is excluded;
    /// 0 when no round ran). Plain decode is 1.0 by construction;
    /// anything above 1.0 is the speculative win.
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            (self.tokens.len().saturating_sub(1)) as f64 / self.rounds.len() as f64
        }
    }

    /// Modeled generation-phase latency (ns): each round's pipelined
    /// verify pass plus its serial draft forwards. Compare against the
    /// summed per-token critical path of plain decode for the modeled
    /// speedup (`benches/decode_throughput.rs`).
    pub fn modeled_generation_ns(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.verify.round_ns + r.draft_cost.latency.critical_ns())
            .sum()
    }
}

fn check_compat(target: &ModelConfig, draft: &ModelConfig) {
    assert_eq!(
        target.vocab, draft.vocab,
        "draft and target must share a vocabulary"
    );
    assert!(
        draft.seq >= target.seq,
        "draft context window ({}) shorter than the target's ({})",
        draft.seq,
        target.seq
    );
}

/// Speculative decode engine: a target [`BatchDecodeEngine`] (one slot
/// — the chunk lanes are *positions*, not sequences) plus a draft
/// [`DecodeEngine`] proposing K tokens per round. See the module docs
/// for the protocol and guarantees.
pub struct SpeculativeEngine {
    target: BatchDecodeEngine,
    draft: DecodeEngine,
    params: CimParams,
    k: usize,
}

impl SpeculativeEngine {
    /// Both models on emulated chips under one mapping strategy (the
    /// draft programs its own, smaller chip).
    pub fn on_chip(
        target: DecodeModel,
        draft: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        k: usize,
    ) -> SpeculativeEngine {
        assert!(k >= 1, "speculation needs K >= 1 (0 means: use DecodeEngine)");
        check_compat(&target.cfg, &draft.cfg);
        let target = BatchDecodeEngine::on_chip(target, params.clone(), strategy, 1);
        let draft = DecodeEngine::on_chip(draft, params.clone(), strategy);
        SpeculativeEngine {
            target,
            draft,
            params,
            k,
        }
    }

    /// Both models on the golden (non-CIM) backend — the functional
    /// reference; costs are zero.
    pub fn reference(target: DecodeModel, draft: DecodeModel, k: usize) -> SpeculativeEngine {
        assert!(k >= 1, "speculation needs K >= 1 (0 means: use DecodeEngine)");
        check_compat(&target.cfg, &draft.cfg);
        SpeculativeEngine {
            target: BatchDecodeEngine::reference(target, 1),
            draft: DecodeEngine::reference(draft),
            params: CimParams::default(),
            k,
        }
    }

    /// The target model.
    pub fn model(&self) -> &DecodeModel {
        &self.target.model
    }

    /// The draft model.
    pub fn draft_model(&self) -> &DecodeModel {
        &self.draft.model
    }

    /// The target chip's mapping (None for the reference backend).
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        self.target.mapping()
    }

    /// The target's key/value cache after the latest run — for
    /// cross-checking rollback against a plain engine. Holds
    /// `prompt + n_tokens - 1` positions after `generate` (the final
    /// emitted token is never fed).
    pub fn kv_cache(&self) -> &KvCache {
        self.target.kv(0)
    }

    /// Greedy speculative generation: feed `prompt`, then emit
    /// `n_tokens` argmax continuations — bit-identical to
    /// [`DecodeEngine::generate`] on the target model, for every draft
    /// and K. Admission rule matches the plain engine: `prompt.len() +
    /// n_tokens` must fit the context window.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> SpeculativeResult {
        assert!(!prompt.is_empty(), "need at least one prompt token");
        assert_fits_context(&self.target.model.cfg, prompt.len(), n_tokens);
        // reset both request states (fresh sequence)
        if self.target.is_active(0) {
            self.target.release(0);
        }
        let slot = self.target.try_admit().expect("capacity-1 pool has a free slot");
        debug_assert_eq!(slot, 0);
        self.draft.reset();

        let mut per_position: Vec<Cost> = Vec::new();
        let mut rounds: Vec<SpecRound> = Vec::new();
        let mut tokens: Vec<i32> = Vec::with_capacity(n_tokens);

        // prefill the target with the whole prompt in one chunked
        // replay; the draft ingests it on its own cache
        self.target.step_chunks(&[(slot, prompt)]);
        per_position.extend(self.target.take_trace(slot));
        for &t in prompt {
            self.draft.forward(t);
        }
        let draft_prefill = sum_costs(&std::mem::take(&mut self.draft.trace.per_token));

        if n_tokens > 0 {
            // the newest emitted token is always "pending": emitted, not
            // yet in the target cache (the invariant every round keeps)
            tokens.push(argmax(self.target.logits(slot)) as i32);

            while tokens.len() < n_tokens {
                let remaining = n_tokens - tokens.len();
                // each round emits at most k_round + 1 tokens; cap so the
                // run never overshoots the request
                let k_round = self.k.min(remaining - 1);
                let pending = *tokens.last().expect("one token is always emitted");

                // --- draft: catch up to the emitted stream, propose ---
                // a zero-proposal round (the request tail) is a plain
                // single-lane verify: the draft has nothing to buy, so
                // it does no work and bills nothing
                let full_len = prompt.len() + tokens.len();
                let mut drafts: Vec<i32> = Vec::with_capacity(k_round);
                if k_round > 0 {
                    while self.draft.kv_len() < full_len {
                        let i = self.draft.kv_len();
                        let t = if i < prompt.len() {
                            prompt[i]
                        } else {
                            tokens[i - prompt.len()]
                        };
                        self.draft.forward(t);
                    }
                    for j in 0..k_round {
                        let d = argmax(self.draft.logits()) as i32;
                        drafts.push(d);
                        if j + 1 < k_round {
                            self.draft.forward(d);
                        }
                    }
                }
                let draft_cost =
                    sum_costs(&std::mem::take(&mut self.draft.trace.per_token));

                // --- target: verify pending + proposals in ONE replay ---
                let base = self.target.kv_len(slot);
                let mut chunk: Vec<i32> = Vec::with_capacity(1 + k_round);
                chunk.push(pending);
                chunk.extend_from_slice(&drafts);
                self.target.step_chunks(&[(slot, chunk.as_slice())]);

                // --- greedy acceptance over the lane argmaxes ---
                // lane j's argmax is the target's true token after
                // chunk[..=j]; lane 0 depends only on `pending`, so its
                // token is always emitted, and each further lane counts
                // only while the proposals keep matching what was emitted
                let mut acc = 0usize;
                let mut last = argmax(self.target.lane_logits(0)) as i32;
                tokens.push(last);
                while acc < k_round && drafts[acc] == last {
                    acc += 1;
                    last = argmax(self.target.lane_logits(acc)) as i32;
                    tokens.push(last);
                }

                // --- rollback: keep pending + accepted, drop the rest ---
                self.target.truncate_kv(slot, base + 1 + acc);
                // honest trace: every lane's record survives the rollback
                per_position.extend(self.target.take_trace(slot));
                // the draft keeps its longest prefix of the emitted stream
                let valid = (full_len + acc).min(self.draft.kv_len());
                self.draft.truncate_kv(valid);

                let verify = match self.target.mapping() {
                    Some(mm) => speculative_round_cost(
                        &self.target.model.cfg,
                        mm,
                        &self.params,
                        base,
                        chunk.len(),
                    ),
                    None => SpeculativeRoundCost {
                        per_lane: vec![Cost::default(); chunk.len()],
                        round_ns: 0.0,
                    },
                };
                rounds.push(SpecRound {
                    base_kv: base,
                    lanes: chunk.len(),
                    proposed: k_round,
                    accepted: acc,
                    verify,
                    draft_cost,
                });
            }
        }

        SpeculativeResult {
            tokens,
            rounds,
            per_position,
            draft_prefill,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn self_draft_shares_the_target_weight_prefix() {
        let cfg = tiny();
        let target = DecodeModel::synth(cfg.clone(), 7);
        let draft = self_draft_model(&cfg, 7, 1);
        assert_eq!(draft.cfg.dec_layers, 1);
        // layer-major op list: the draft's 6 ops are the target's first 6
        assert_eq!(draft.ops.len(), 6);
        for (i, (dw, tw)) in draft.weights.iter().zip(&target.weights).enumerate() {
            for (dt, tt) in dw.tiles.iter().zip(&tw.tiles) {
                assert_eq!(dt.l.data, tt.l.data, "op {i}: L factor drifted");
                assert_eq!(dt.r.data, tt.r.data, "op {i}: R factor drifted");
            }
        }
        assert_eq!(draft.embedding.data, target.embedding.data);
        assert_eq!(draft.lm_head.data, target.lm_head.data);
        // full depth is capped, not extended; 0 means full depth
        let full = self_draft_model(&cfg, 7, 99);
        assert_eq!(full.cfg.dec_layers, cfg.dec_layers);
        let default_full = self_draft_model(&cfg, 7, 0);
        assert_eq!(default_full.cfg.dec_layers, cfg.dec_layers);
    }

    #[test]
    fn perfect_self_draft_accepts_everything() {
        // a full-depth self-draft IS the target, so every proposal is
        // the target's own argmax: acceptance rate 1, rounds emit K+1
        let cfg = tiny();
        let target = DecodeModel::synth(cfg.clone(), 11);
        let draft = self_draft_model(&cfg, 11, cfg.dec_layers);
        let mut spec = SpeculativeEngine::reference(target, draft, 4);
        let prompt = [3i32, 9, 27];
        let r = spec.generate(&prompt, 11);
        assert_eq!(r.tokens.len(), 11);
        assert!(r.total_proposed() > 0);
        assert_eq!(r.total_accepted(), r.total_proposed(), "perfect draft rejected");
        assert_eq!(r.acceptance_rate(), 1.0);
        assert!(r.tokens_per_round() > 1.0, "no speculative win");
        // bit-identical to plain greedy decode
        let mut plain = DecodeEngine::reference(DecodeModel::synth(cfg, 11));
        assert_eq!(r.tokens, plain.generate(&prompt, 11).tokens);
    }

    #[test]
    fn mismatched_draft_still_decodes_exactly() {
        // a draft from a different seed disagrees almost everywhere:
        // rounds reject, the KV rolls back, and the output must still be
        // bit-identical to plain greedy decode
        let cfg = tiny();
        let target = DecodeModel::synth(cfg.clone(), 5);
        let draft = DecodeModel::synth(cfg.clone(), 500);
        let mut spec = SpeculativeEngine::reference(target, draft, 4);
        let prompt = [1i32, 2];
        let r = spec.generate(&prompt, 10);
        let mut plain = DecodeEngine::reference(DecodeModel::synth(cfg, 5));
        let want = plain.generate(&prompt, 10);
        assert_eq!(r.tokens, want.tokens, "rollback corrupted the sequence");
        assert!(
            r.rounds.iter().any(|rd| rd.accepted < rd.proposed),
            "expected at least one rejection from an unrelated draft"
        );
        // the rejected lanes stay on the bill
        let fed: usize = r.rounds.iter().map(|rd| rd.lanes).sum();
        assert_eq!(r.per_position.len(), prompt.len() + fed);
    }

    #[test]
    fn engine_reuse_is_reset_safe() {
        let cfg = tiny();
        let mut spec = SpeculativeEngine::reference(
            DecodeModel::synth(cfg.clone(), 21),
            self_draft_model(&cfg, 21, 1),
            2,
        );
        let _ = spec.generate(&[9, 1, 7], 6); // dirty both caches
        let reused = spec.generate(&[3, 4], 6);
        let mut plain = DecodeEngine::reference(DecodeModel::synth(cfg, 21));
        assert_eq!(reused.tokens, plain.generate(&[3, 4], 6).tokens);
        // final cache: prompt + n - 1 (the last emitted token is never fed)
        assert_eq!(spec.kv_cache().len(), 2 + 6 - 1);
    }

    #[test]
    #[should_panic(expected = "exceeds the context window")]
    fn speculative_generate_rejects_overlong_requests() {
        let cfg = tiny();
        let mut spec = SpeculativeEngine::reference(
            DecodeModel::synth(cfg.clone(), 3),
            self_draft_model(&cfg, 3, 1),
            2,
        );
        let _ = spec.generate(&[1, 2, 3, 4], cfg.seq);
    }
}
