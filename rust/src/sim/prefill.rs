//! Chunked prefill: position-parallel prompt ingestion (DESIGN.md §6c).
//!
//! Decode advances one position per replay because each token depends on
//! the previous one — but *prompt* positions are known up front, and for
//! the six Para matmuls of every layer they are mutually independent.
//! Since the paper's mapping keeps all weights resident in the CIM
//! arrays, a chunk of C prompt positions can ride the same batched pass
//! tables PR 3 built for multi-sequence decode, with **lanes =
//! positions**: one `Crossbar::mvm_batch_cols` pass reads each
//! programmed cell once and updates C accumulators (stride-C interleaved
//! staging), so an S-token prompt costs S/C replay walks instead of S.
//! Everything order-dependent — LayerNorm, causal attention (a position
//! attends to the KV entries of all *earlier* positions in its own chunk
//! plus the cache), residuals and the LM head — still runs per position,
//! which is exactly what keeps chunked ingestion **bit-identical** to
//! token-by-token [`super::decode::DecodeEngine::generate`]
//! (`tests/prop_prefill.rs`).
//!
//! The module provides:
//! * [`KvCache`] — the per-request key/value state both engines share.
//! * [`ChunkWorkspace`] — lane-major activation buffers plus the
//!   stride-interleaved staging the batched replay consumes; allocated
//!   once, grown on demand, reused every step.
//! * [`chunk_step`] — one mixed step: any set of slots, each advancing
//!   by a variable-length token chunk (decode lanes are chunks of 1),
//!   through ONE batched replay of every Para op.
//! * [`allocate_chunks`] — the anti-starvation lane allocator the
//!   continuous-batching scheduler uses to bound prefill chunks so
//!   decode lanes of in-flight requests always step.

use crate::cim::{CimParams, Cost};
use crate::mapping::ModelMapping;
use crate::model::ModelConfig;
use crate::sim::decode::{
    attend_into, gelu, layer_norm_into, BatchSlot, DecodeModel, LayerOps, ParaBackend,
};
use crate::sim::trace::decode_token_cost;

/// Per-request key/value cache: one d-vector per cached position per
/// layer. This is the only *state* a request carries between steps —
/// everything else the engines touch is reusable scratch.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// `keys[layer][pos]` is the cached key vector (length d).
    pub(crate) keys: Vec<Vec<Vec<f32>>>,
    pub(crate) values: Vec<Vec<Vec<f32>>>,
}

impl KvCache {
    pub fn new(layers: usize) -> Self {
        Self {
            keys: vec![Vec::new(); layers],
            values: vec![Vec::new(); layers],
        }
    }

    /// Number of decoder layers the cache spans.
    pub fn layers(&self) -> usize {
        self.keys.len()
    }

    /// Cached positions so far (identical across layers).
    pub fn len(&self) -> usize {
        self.keys.first().map(|k| k.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cached key vector of `pos` in `layer`.
    pub fn key(&self, layer: usize, pos: usize) -> &[f32] {
        &self.keys[layer][pos]
    }

    /// Cached value vector of `pos` in `layer`.
    pub fn value(&self, layer: usize, pos: usize) -> &[f32] {
        &self.values[layer][pos]
    }

    /// Append one position's K/V to `layer`.
    pub(crate) fn push(&mut self, layer: usize, k: Vec<f32>, v: Vec<f32>) {
        self.keys[layer].push(k);
        self.values[layer].push(v);
    }

    /// Roll the cache back to `len` positions, dropping every later
    /// entry in every layer — the speculative-decoding rejection path
    /// (`sim::speculate`, DESIGN.md §6d). A position's K/V depend only
    /// on the tokens up to that position, so a truncated cache is
    /// bitwise indistinguishable from one that never saw the dropped
    /// tokens (`tests/prop_speculative.rs` pins this). `truncate(0)`
    /// empties the cache exactly like [`KvCache::clear`]; truncating to
    /// the current length is a no-op. Rollback never invents state:
    /// `len` beyond the cached length is a caller bug and panics.
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len(),
            "KV rollback cannot extend the cache: truncate({len}) > cached {}",
            self.len()
        );
        for k in self.keys.iter_mut() {
            k.truncate(len);
        }
        for v in self.values.iter_mut() {
            v.truncate(len);
        }
    }

    /// Clone the first `len` cached positions into a fresh cache — the
    /// shared-prefix store's snapshot path (`coordinator::server`,
    /// DESIGN.md §6g). The copy is bitwise, and a position's K/V depend
    /// only on the tokens at and before it, so a cloned prefix spliced
    /// under the same leading tokens is indistinguishable from having
    /// prefilled those positions in place (`tests/prop_prefix_cache.rs`
    /// pins this). Like [`KvCache::truncate`], `len` beyond the cached
    /// length is a caller bug and panics.
    pub fn clone_prefix(&self, len: usize) -> KvCache {
        assert!(
            len <= self.len(),
            "prefix clone cannot extend the cache: clone_prefix({len}) > cached {}",
            self.len()
        );
        KvCache {
            keys: self.keys.iter().map(|k| k[..len].to_vec()).collect(),
            values: self.values.iter().map(|v| v[..len].to_vec()).collect(),
        }
    }

    /// Drop every cached position (request teardown).
    pub(crate) fn clear(&mut self) {
        for k in self.keys.iter_mut() {
            k.clear();
        }
        for v in self.values.iter_mut() {
            v.clear();
        }
    }
}

/// Lane-major activation workspace of one chunked step: lane `l`'s
/// d-vector for buffer `h` lives at `h[l*d..(l+1)*d]`. One workspace per
/// [`super::decode::BatchDecodeEngine`], sized to the largest lane count
/// seen so far (`ensure`), so the steady-state step loop allocates
/// nothing.
#[derive(Clone, Debug)]
pub(crate) struct ChunkWorkspace {
    d: usize,
    d_ff: usize,
    vocab: usize,
    /// Lane capacity the buffers are currently sized for.
    lanes: usize,
    /// Residual stream per lane (lanes x d).
    pub(crate) h: Vec<f32>,
    /// LayerNorm output feeding the current sub-block (lanes x d).
    pub(crate) x: Vec<f32>,
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    /// Attention context per lane (lanes x d).
    pub(crate) ctx: Vec<f32>,
    pub(crate) o: Vec<f32>,
    /// FFN hidden per lane (lanes x d_ff).
    pub(crate) f: Vec<f32>,
    pub(crate) g: Vec<f32>,
    /// Final LayerNorm output per lane (lanes x d).
    pub(crate) hn: Vec<f32>,
    /// LM-head logits per lane (lanes x vocab) — the per-position
    /// logits of the latest step, in flattened input order.
    pub(crate) logits: Vec<f32>,
    /// Stride-L interleaved staging (op input) buffer, lanes x
    /// max(d, d_ff) wide.
    xb: Vec<f32>,
    /// Stride-L interleaved landing (op output) buffer.
    yb: Vec<f32>,
}

impl ChunkWorkspace {
    pub(crate) fn new(cfg: &ModelConfig, lanes: usize) -> Self {
        let mut ws = Self {
            d: cfg.d_model,
            d_ff: cfg.d_ff,
            vocab: cfg.vocab,
            lanes: 0,
            h: Vec::new(),
            x: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            ctx: Vec::new(),
            o: Vec::new(),
            f: Vec::new(),
            g: Vec::new(),
            hn: Vec::new(),
            logits: Vec::new(),
            xb: Vec::new(),
            yb: Vec::new(),
        };
        ws.ensure(lanes.max(1));
        ws
    }

    /// Grow every buffer to hold `lanes` lanes (never shrinks, so a
    /// fixed serving configuration reaches a zero-allocation steady
    /// state after its widest step).
    pub(crate) fn ensure(&mut self, lanes: usize) {
        if lanes <= self.lanes {
            return;
        }
        let d = self.d;
        for buf in [
            &mut self.h,
            &mut self.x,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.ctx,
            &mut self.o,
            &mut self.g,
            &mut self.hn,
        ] {
            buf.resize(d * lanes, 0.0);
        }
        self.f.resize(self.d_ff * lanes, 0.0);
        self.logits.resize(self.vocab * lanes, 0.0);
        let wide = self.d.max(self.d_ff);
        self.xb.resize(wide * lanes, 0.0);
        self.yb.resize(wide * lanes, 0.0);
        self.lanes = lanes;
    }

    /// Logits of lane `lane` from the latest step (flattened input
    /// order: groups in call order, positions in chunk order).
    pub(crate) fn lane_logits(&self, lane: usize) -> &[f32] {
        &self.logits[lane * self.vocab..(lane + 1) * self.vocab]
    }
}

/// Gather lane-major rows into the stride-L interleaved staging buffer:
/// `out[c * lanes + l] = rows[l * width + c]` — the layout
/// `FunctionalChip::run_op_batch_into` consumes.
fn pack_rows(rows: &[f32], width: usize, lanes: usize, out: &mut [f32]) {
    for l in 0..lanes {
        let src = &rows[l * width..(l + 1) * width];
        for (c, &v) in src.iter().enumerate() {
            out[c * lanes + l] = v;
        }
    }
}

/// Scatter the stride-L interleaved landing buffer back into lane-major
/// rows (inverse of [`pack_rows`]).
fn unpack_rows(interleaved: &[f32], width: usize, lanes: usize, rows: &mut [f32]) {
    for l in 0..lanes {
        let dst = &mut rows[l * width..(l + 1) * width];
        for (c, dv) in dst.iter_mut().enumerate() {
            *dv = interleaved[c * lanes + l];
        }
    }
}

/// Anti-starvation lane allocator for one chunked step: every requester
/// gets at least one lane (an in-flight request always advances — a
/// large prefill can never stall its neighbours' decode lanes), then the
/// remaining budget is dealt round-robin up to each requester's want.
/// With `budget < wants.len()` the floor still holds: progress trumps
/// the budget.
pub fn allocate_chunks(wants: &[usize], budget: usize) -> Vec<usize> {
    let mut alloc: Vec<usize> = wants.iter().map(|&w| w.min(1)).collect();
    let mut total: usize = alloc.iter().sum();
    loop {
        let mut progressed = false;
        for (a, &w) in alloc.iter_mut().zip(wants) {
            if total >= budget {
                return alloc;
            }
            if *a < w {
                *a += 1;
                total += 1;
                progressed = true;
            }
        }
        if !progressed {
            return alloc;
        }
    }
}

/// Advance each listed slot by its token chunk — decode lanes are chunks
/// of length 1, prefill lanes bring C prompt positions — through ONE
/// batched replay of every Para op (lanes = Σ chunk lengths, stride-L
/// interleaved). Per slot, per position the f32 operations are exactly
/// the token-by-token path's, in the same order:
///
/// 1. embedding + positional per lane at the lane's own position;
/// 2. per layer: LayerNorm per lane → batched wq/wk/wv → K/V appended to
///    the slot's cache *in position order* → causal attention per lane
///    against the cache prefix `[..pos+1]` (earlier chunk positions are
///    visible, later ones are not) → batched wo → residual → LayerNorm →
///    batched ffn1 → GeLU per lane → batched ffn2 → residual;
/// 3. final LayerNorm + untied LM head per lane (per-position logits
///    land in the workspace, the chunk's last logits in the slot).
///
/// Costs are recorded per position via `trace::decode_token_cost` at
/// the position's KV length — identical to token-by-token records (the
/// physical per-position analog/ADC work is unchanged; what chunking
/// amortizes is the per-replay command overhead). The chunk-level
/// pipelined-latency model lives in `trace::prefill_chunk_cost` and is
/// consumed by the reporting layer (bench sweep), not this hot loop.
///
/// The caller (`BatchDecodeEngine::step_chunks`) validates slots and
/// context-window bounds before delegating here.
pub(crate) fn chunk_step(
    model: &DecodeModel,
    backend: &mut ParaBackend,
    params: &CimParams,
    slots: &mut [BatchSlot],
    ws: &mut ChunkWorkspace,
    inputs: &[(usize, &[i32])],
) {
    let lanes: usize = inputs.iter().map(|&(_, toks)| toks.len()).sum();
    ws.ensure(lanes);
    // cache length of every group BEFORE any K/V append this step
    let bases: Vec<usize> = inputs.iter().map(|&(si, _)| slots[si].kv.len()).collect();
    embed_chunk(model, ws, inputs, &bases);
    for l in 0..model.cfg.dec_layers {
        layer_chunk(
            model,
            backend,
            model.layers[l],
            l,
            slots,
            ws,
            inputs,
            &bases,
            lanes,
        );
    }
    head_chunk(model, ws, lanes);
    let mapping = match backend {
        ParaBackend::Chip(chip) => Some(&chip.mapping),
        ParaBackend::Reference => None,
    };
    finish_chunk(&model.cfg, mapping, params, slots, ws, inputs, &bases);
}

/// Token + positional embedding for every lane of one chunked step, at
/// each lane's own cache position (`bases[g] + offset`), into the
/// residual stream `ws.h`. The caller has already `ensure`d the
/// workspace for the step's lane count.
pub(crate) fn embed_chunk(
    model: &DecodeModel,
    ws: &mut ChunkWorkspace,
    inputs: &[(usize, &[i32])],
    bases: &[usize],
) {
    let d = model.cfg.d_model;
    let vocab = model.cfg.vocab;
    let mut lane = 0usize;
    for (gi, &(_, toks)) in inputs.iter().enumerate() {
        for (off, &token) in toks.iter().enumerate() {
            let pos = bases[gi] + off;
            let tok = (token.max(0) as usize).min(vocab - 1);
            let hrow = &mut ws.h[lane * d..(lane + 1) * d];
            for ((hv, e), p) in hrow
                .iter_mut()
                .zip(model.embedding.row(tok))
                .zip(model.positional.row(pos))
            {
                *hv = e + p;
            }
            lane += 1;
        }
    }
}

/// One decoder layer of a chunked step, over all lanes: the pre-LN
/// attention sub-block (batched wq/wk/wv, K/V appended in position
/// order, causal attention against the cache prefix, batched wo) then
/// the pre-LN feed-forward sub-block. `ops` must index the *given
/// backend's* op space — the whole-model op list for the single-chip
/// engine, the stage-local list for a sharded stage chip
/// (`sim::shard`) — while `kv_layer` is always the **global** layer
/// index into the slot caches, so a stage writes exactly its layer
/// range of each slot's KV. Splitting the layer loop here is what lets
/// the sharded engine run layers `[lo..hi)` per chip with the per-lane
/// f32 order untouched (the bit-identity argument, DESIGN.md §6f).
pub(crate) fn layer_chunk(
    model: &DecodeModel,
    backend: &mut ParaBackend,
    ops: LayerOps,
    kv_layer: usize,
    slots: &mut [BatchSlot],
    ws: &mut ChunkWorkspace,
    inputs: &[(usize, &[i32])],
    bases: &[usize],
    lanes: usize,
) {
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let d_ff = cfg.d_ff;
    let heads = cfg.n_heads;
    let dh = cfg.d_head();
    let l = kv_layer;
    let ChunkWorkspace {
        h,
        x,
        q,
        k,
        v,
        ctx,
        o,
        f,
        g,
        xb,
        yb,
        ..
    } = ws;
    {
        // --- self-attention sub-block (pre-LN) ---
        for lane in 0..lanes {
            layer_norm_into(&h[lane * d..(lane + 1) * d], &mut x[lane * d..(lane + 1) * d]);
        }
        pack_rows(x, d, lanes, xb);
        backend.run_batch_into(model, ops.wq, lanes, &xb[..d * lanes], &mut yb[..d * lanes]);
        unpack_rows(yb, d, lanes, q);
        backend.run_batch_into(model, ops.wk, lanes, &xb[..d * lanes], &mut yb[..d * lanes]);
        unpack_rows(yb, d, lanes, k);
        backend.run_batch_into(model, ops.wv, lanes, &xb[..d * lanes], &mut yb[..d * lanes]);
        unpack_rows(yb, d, lanes, v);
        // K/V append in position order, then causal attention per lane:
        // position `base + off` sees the cache prefix `[..base + off + 1]`
        // — exactly the token-by-token view (earlier chunkmates included,
        // later ones masked by the prefix bound).
        {
            let mut lane = 0usize;
            for (gi, &(si, toks)) in inputs.iter().enumerate() {
                let slot = &mut slots[si];
                for off in 0..toks.len() {
                    let kr = &k[(lane + off) * d..(lane + off + 1) * d];
                    let vr = &v[(lane + off) * d..(lane + off + 1) * d];
                    slot.kv.push(l, kr.to_vec(), vr.to_vec());
                }
                let base = bases[gi];
                for off in 0..toks.len() {
                    let qrow = &q[(lane + off) * d..(lane + off + 1) * d];
                    let crow = &mut ctx[(lane + off) * d..(lane + off + 1) * d];
                    attend_into(
                        qrow,
                        &slot.kv.keys[l][..base + off + 1],
                        &slot.kv.values[l][..base + off + 1],
                        heads,
                        dh,
                        &mut slot.scores,
                        crow,
                    );
                }
                lane += toks.len();
            }
        }
        pack_rows(ctx, d, lanes, xb);
        backend.run_batch_into(model, ops.wo, lanes, &xb[..d * lanes], &mut yb[..d * lanes]);
        unpack_rows(yb, d, lanes, o);
        // --- feed-forward sub-block (pre-LN) ---
        for lane in 0..lanes {
            {
                let hrow = &mut h[lane * d..(lane + 1) * d];
                let orow = &o[lane * d..(lane + 1) * d];
                for (hv, ov) in hrow.iter_mut().zip(orow) {
                    *hv += ov;
                }
            }
            layer_norm_into(&h[lane * d..(lane + 1) * d], &mut x[lane * d..(lane + 1) * d]);
        }
        pack_rows(x, d, lanes, xb);
        backend.run_batch_into(
            model,
            ops.ffn1,
            lanes,
            &xb[..d * lanes],
            &mut yb[..d_ff * lanes],
        );
        unpack_rows(yb, d_ff, lanes, f);
        for lane in 0..lanes {
            gelu(&mut f[lane * d_ff..(lane + 1) * d_ff]);
        }
        pack_rows(f, d_ff, lanes, xb);
        backend.run_batch_into(
            model,
            ops.ffn2,
            lanes,
            &xb[..d_ff * lanes],
            &mut yb[..d * lanes],
        );
        unpack_rows(yb, d, lanes, g);
        for lane in 0..lanes {
            let hrow = &mut h[lane * d..(lane + 1) * d];
            let grow = &g[lane * d..(lane + 1) * d];
            for (hv, gv) in hrow.iter_mut().zip(grow) {
                *hv += gv;
            }
        }
    }
}

/// Final LayerNorm + untied LM head for every lane of one chunked step
/// (per-position logits land in `ws.logits`; every position's logits
/// are observable — teacher-forced serving streams them all).
pub(crate) fn head_chunk(model: &DecodeModel, ws: &mut ChunkWorkspace, lanes: usize) {
    let d = model.cfg.d_model;
    let vocab = model.cfg.vocab;
    let ChunkWorkspace { h, hn, logits, .. } = ws;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for lane in 0..lanes {
        layer_norm_into(&h[lane * d..(lane + 1) * d], &mut hn[lane * d..(lane + 1) * d]);
        let hrow = &hn[lane * d..(lane + 1) * d];
        let lrow = &mut logits[lane * vocab..(lane + 1) * vocab];
        for (t, lv) in lrow.iter_mut().enumerate() {
            let row = model.lm_head.row(t);
            let mut acc = 0.0f32;
            for (r, xv) in row.iter().zip(hrow) {
                acc += r * xv;
            }
            *lv = acc * inv_sqrt_d;
        }
    }
}

/// Per-slot epilogue of one chunked step: persist each chunk's last
/// logits (the argmax source for a continuation step) and record one
/// cost per position at the position's own KV length, priced against
/// the given **whole-model** mapping (`None` = reference backend,
/// zero-cost records). The sharded engine passes its 1-chip reference
/// mapping here so per-position records stay bitwise identical to
/// single-chip replay — sharding relocates work, the bill per position
/// does not change; the pipeline win is modeled separately
/// (`trace::pipeline_timeline`).
pub(crate) fn finish_chunk(
    cfg: &ModelConfig,
    mapping: Option<&ModelMapping>,
    params: &CimParams,
    slots: &mut [BatchSlot],
    ws: &ChunkWorkspace,
    inputs: &[(usize, &[i32])],
    bases: &[usize],
) {
    let vocab = cfg.vocab;
    let logits = &ws.logits;
    let mut lane = 0usize;
    for (gi, &(si, toks)) in inputs.iter().enumerate() {
        let c = toks.len();
        let slot = &mut slots[si];
        let last = lane + c - 1;
        slot.logits
            .copy_from_slice(&logits[last * vocab..(last + 1) * vocab]);
        match mapping {
            Some(mm) => {
                for i in 0..c {
                    slot.trace
                        .record(decode_token_cost(cfg, mm, params, bases[gi] + i + 1));
                }
            }
            None => {
                for _ in 0..c {
                    slot.trace.record(Cost::default());
                }
            }
        }
        lane += c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_cache_tracks_positions_per_layer() {
        let mut kv = KvCache::new(2);
        assert_eq!(kv.layers(), 2);
        assert!(kv.is_empty());
        kv.push(0, vec![1.0], vec![2.0]);
        kv.push(1, vec![3.0], vec![4.0]);
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.key(1, 0), &[3.0]);
        assert_eq!(kv.value(0, 0), &[2.0]);
        kv.clear();
        assert_eq!(kv.len(), 0);
    }

    #[test]
    fn kv_truncate_drops_positions_and_agrees_with_clear() {
        let mut kv = KvCache::new(2);
        for pos in 0..4 {
            kv.push(0, vec![pos as f32], vec![10.0 + pos as f32]);
            kv.push(1, vec![20.0 + pos as f32], vec![30.0 + pos as f32]);
        }
        // truncate == current length is a no-op
        kv.truncate(4);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.key(0, 3), &[3.0]);
        // mid rollback drops exactly the tail, in every layer
        kv.truncate(2);
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.key(0, 1), &[1.0]);
        assert_eq!(kv.value(1, 1), &[31.0]);
        // truncate-then-extend == never-having-extended (bitwise)
        kv.push(0, vec![9.0], vec![9.5]);
        kv.push(1, vec![9.1], vec![9.6]);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.key(0, 2), &[9.0]);
        // truncate(0) and clear agree (ISSUE-5 regression): both leave
        // an empty cache with the layer structure intact
        let mut cleared = kv.clone();
        cleared.clear();
        kv.truncate(0);
        assert_eq!(kv.len(), cleared.len());
        assert_eq!(kv.layers(), cleared.layers());
        assert!(kv.is_empty() && cleared.is_empty());
    }

    #[test]
    #[should_panic(expected = "cannot extend")]
    fn kv_truncate_rejects_lengthening() {
        let mut kv = KvCache::new(1);
        kv.push(0, vec![1.0], vec![2.0]);
        kv.truncate(2);
    }

    #[test]
    fn pack_unpack_round_trip() {
        let lanes = 3;
        let width = 4;
        let rows: Vec<f32> = (0..lanes * width).map(|i| i as f32).collect();
        let mut inter = vec![0.0f32; lanes * width];
        pack_rows(&rows, width, lanes, &mut inter);
        // spot-check the stride layout: element c of lane l at c*lanes+l
        assert_eq!(inter[0 * lanes + 1], rows[1 * width + 0]);
        assert_eq!(inter[3 * lanes + 2], rows[2 * width + 3]);
        let mut back = vec![0.0f32; lanes * width];
        unpack_rows(&inter, width, lanes, &mut back);
        assert_eq!(rows, back);
    }

    #[test]
    fn allocate_chunks_floors_and_budgets() {
        // everyone gets >= 1 even when the budget is too small
        assert_eq!(allocate_chunks(&[4, 4, 4], 2), vec![1, 1, 1]);
        // round-robin the surplus
        assert_eq!(allocate_chunks(&[4, 4], 6), vec![3, 3]);
        assert_eq!(allocate_chunks(&[4, 1], 6), vec![4, 1]);
        // never over-allocate past the want
        assert_eq!(allocate_chunks(&[2, 3], 100), vec![2, 3]);
        // uneven split favours earlier requesters by at most one lane
        assert_eq!(allocate_chunks(&[8, 8], 5), vec![3, 2]);
        assert_eq!(allocate_chunks(&[], 8), Vec::<usize>::new());
    }

    #[test]
    fn workspace_grows_and_reuses() {
        let cfg = ModelConfig::tiny();
        let mut ws = ChunkWorkspace::new(&cfg, 2);
        assert_eq!(ws.h.len(), 2 * cfg.d_model);
        ws.ensure(5);
        assert_eq!(ws.f.len(), 5 * cfg.d_ff);
        assert_eq!(ws.logits.len(), 5 * cfg.vocab);
        let ptr = ws.h.as_ptr();
        ws.ensure(3); // never shrinks, no realloc
        assert_eq!(ws.h.as_ptr(), ptr);
        assert_eq!(ws.h.len(), 5 * cfg.d_model);
    }
}
