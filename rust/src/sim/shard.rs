//! Multi-chip layer sharding with pipeline-parallel microbatch decode
//! (DESIGN.md §6f).
//!
//! One programmed chip holds the whole model, so decode latency is the
//! serial sum of every layer's analog passes and model size is capped
//! by a single chip's array budget — the multi-macro scale-out problem
//! the CIM survey in PAPERS.md (arxiv 2406.08413) calls open for
//! LLM-scale CIM. This module shards a [`DecodeModel`]'s decoder
//! layers across N [`FunctionalChip`]s as **contiguous layer ranges**
//! (stage 0 additionally owns the embedding, the last stage the final
//! LayerNorm + LM head, both digital) and drives them as a pipeline
//! with in-flight microbatches: while chip `k` runs microbatch `m`'s
//! layers, chip `k-1` runs microbatch `m+1`'s.
//!
//! **Functional execution vs latency model.** The functional simulator
//! is host-serial: a sharded step runs every stage in layer order over
//! the step's lanes, so each lane replays *exactly* the f32 operations
//! of the single-chip path, in the same order — only the chip (and
//! hence the pass-table subset) executing each layer changes. Monarch
//! chips are bitwise equal to the `RectMonarch` reference per op
//! regardless of which mapping subset holds the op, and every digital
//! op (LayerNorm, attention, GeLU, residuals, LM head) runs per lane in
//! `sim::prefill`'s fixed order — so sharded replay is **bit-identical
//! to single-chip replay token-for-token** (`tests/prop_shard.rs`).
//! The pipeline *overlap* lives in the latency model: per step, each
//! (stage, microbatch) pair gets an analog window priced by the stage's
//! own mapping, inter-chip activation hand-offs are charged per hop
//! (`trace::shard_transfer_cost`), and the classic pipeline recurrence
//! (`trace::pipeline_timeline`) overlaps the windows — near-N× steady
//! state throughput once ≥ N microbatches are in flight.
//!
//! **KV partition.** Each slot's [`KvCache`](crate::sim::prefill::KvCache)
//! rows are split by layer range: stage `s` reads and writes only
//! layers `[lo..hi)` of every slot's cache (a physical multi-chip
//! build would keep those rows in chip `s`'s local memory). The cache
//! object itself stays whole so every existing KV API — truncation,
//! speculative rollback, the differential props — works unchanged.

use crate::cim::{AnalogMode, CimParams};
use crate::mapping::{map_ops, ModelMapping, Strategy};
use crate::model::MatmulOp;
use crate::monarch::RectMonarch;
use crate::sim::decode::{BatchSlot, DecodeModel, LayerOps, ParaBackend};
use crate::sim::exec::{FunctionalChip, ReplayMode};
use crate::sim::prefill::{self, ChunkWorkspace};
use crate::sim::trace::{
    self, pipeline_timeline, prefill_chunk_cost, PipelineTimeline,
};

/// Contiguous layer ranges `[lo, hi)` of an `n_layers`-deep model split
/// across (up to) `shards` pipeline stages. The stage count clamps to
/// `n_layers` (a stage always holds at least one layer) and to at least
/// one; earlier stages take the extra layer when the split is uneven,
/// so depths differ by at most one.
pub fn stage_ranges(n_layers: usize, shards: usize) -> Vec<(usize, usize)> {
    assert!(n_layers > 0, "cannot shard a zero-layer model");
    let stages = shards.clamp(1, n_layers);
    let base = n_layers / stages;
    let extra = n_layers % stages;
    let mut ranges = Vec::with_capacity(stages);
    let mut lo = 0usize;
    for s in 0..stages {
        let len = base + usize::from(s < extra);
        ranges.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, n_layers);
    ranges
}

/// One pipeline stage: a chip programmed with a contiguous layer
/// range's Para ops, plus the op-index remap that makes the shared
/// layer loop (`sim::prefill::layer_chunk`) address it.
pub(crate) struct ShardStage {
    /// First global layer index on this chip.
    pub(crate) lo: usize,
    /// One past the last global layer index.
    pub(crate) hi: usize,
    /// The stage's programmed chip (always `ParaBackend::Chip`).
    pub(crate) backend: ParaBackend,
    /// Per layer in `[lo..hi)`, the six Para op indices in the *stage
    /// chip's* op space (`program_rect` renumbers the subset 0-based).
    pub(crate) layer_ops: Vec<LayerOps>,
}

impl ShardStage {
    /// Layer count resident on this chip.
    pub(crate) fn depth(&self) -> usize {
        self.hi - self.lo
    }

    /// The stage chip's mapping (prices exactly this stage's Para+DPU
    /// work — `per_token_cost` iterates only the layers present).
    pub(crate) fn mapping(&self) -> &ModelMapping {
        match &self.backend {
            ParaBackend::Chip(chip) => &chip.mapping,
            ParaBackend::Reference => unreachable!("stages are always chips"),
        }
    }
}

/// A [`DecodeModel`] programmed across N chips as a layer-sharded
/// pipeline, plus the 1-chip reference mapping that keeps per-position
/// cost records bitwise identical to single-chip replay.
pub struct ShardedBackend {
    pub(crate) stages: Vec<ShardStage>,
    /// The whole model mapped onto ONE chip — the serial baseline the
    /// pipeline is measured against, and the mapping per-position cost
    /// records are priced with (identical to `BatchDecodeEngine::on_chip`).
    full_mapping: ModelMapping,
}

impl ShardedBackend {
    /// Program the model's layers across (up to) `shards` chips under
    /// one mapping strategy, pre-growing each chip's batched scratch
    /// for `lanes` concurrent lanes. Stage `s` gets the ops and weights
    /// of layers `stage_ranges[s]` — `FunctionalChip::program_rect`
    /// over the subset, so each op's placements, compiled pass tables
    /// and replay are exactly what a dedicated chip would hold.
    pub fn program(
        model: &DecodeModel,
        params: &CimParams,
        strategy: Strategy,
        shards: usize,
        lanes: usize,
    ) -> ShardedBackend {
        Self::program_analog(model, params, strategy, shards, lanes, None)
    }

    /// [`ShardedBackend::program`] with opt-in analog realism: every
    /// stage chip is programmed under the same [`AnalogMode`]
    /// (DESIGN.md §6i). At ideal settings this is bit-identical to the
    /// exact sharded path (and hence to single-chip replay); under
    /// noise, each stage corrupts from its own chip-local array streams
    /// (`Pcg32::stream(seed, i)` over the stage's 0-based array index),
    /// so a sharded chip's corruption pattern differs from the mono
    /// chip's — bit-identity to mono is only promised at ideal settings.
    pub fn program_analog(
        model: &DecodeModel,
        params: &CimParams,
        strategy: Strategy,
        shards: usize,
        lanes: usize,
        analog: Option<&AnalogMode>,
    ) -> ShardedBackend {
        let cfg = &model.cfg;
        let full_mapping = map_ops(cfg, &model.ops, params, strategy);
        let stages = stage_ranges(cfg.dec_layers, shards)
            .into_iter()
            .map(|(lo, hi)| {
                // global op indices of this stage's layers, ascending
                let mut globals: Vec<usize> = Vec::new();
                for l in lo..hi {
                    let o = model.layers[l];
                    globals.extend_from_slice(&[o.wq, o.wk, o.wv, o.wo, o.ffn1, o.ffn2]);
                }
                globals.sort_unstable();
                let local_of = |g: usize| -> usize {
                    globals.binary_search(&g).expect("op belongs to this stage")
                };
                let ops: Vec<MatmulOp> =
                    globals.iter().map(|&g| model.ops[g].clone()).collect();
                let weights: Vec<RectMonarch> =
                    globals.iter().map(|&g| model.weights[g].clone()).collect();
                let mut chip = FunctionalChip::program_rect_analog(
                    cfg, &ops, &weights, params, strategy, analog,
                );
                chip.warm_batch(lanes);
                let layer_ops = (lo..hi)
                    .map(|l| {
                        let o = model.layers[l];
                        LayerOps {
                            wq: local_of(o.wq),
                            wk: local_of(o.wk),
                            wv: local_of(o.wv),
                            wo: local_of(o.wo),
                            ffn1: local_of(o.ffn1),
                            ffn2: local_of(o.ffn2),
                        }
                    })
                    .collect();
                ShardStage {
                    lo,
                    hi,
                    backend: ParaBackend::Chip(Box::new(chip)),
                    layer_ops,
                }
            })
            .collect();
        ShardedBackend {
            stages,
            full_mapping,
        }
    }

    /// Number of pipeline stages (chips).
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// The contiguous layer range `[lo, hi)` of each stage.
    pub fn ranges(&self) -> Vec<(usize, usize)> {
        self.stages.iter().map(|s| (s.lo, s.hi)).collect()
    }

    /// The 1-chip reference mapping of the whole model.
    pub fn full_mapping(&self) -> &ModelMapping {
        &self.full_mapping
    }

    /// Select the pass-table replay encoding on every stage chip.
    pub fn set_replay_mode(&mut self, mode: ReplayMode) {
        for stage in &mut self.stages {
            if let ParaBackend::Chip(chip) = &mut stage.backend {
                chip.set_replay_mode(mode);
            }
        }
    }
}

/// One pipelined sharded step: advance each listed slot by its token
/// chunk through every stage in layer order (each microbatch's f32
/// stream is exactly the single-chip `chunk_step`'s — see the module
/// docs for why that makes sharded replay bit-identical), then build
/// the step's per-stage timeline: stage `s`'s window for microbatch
/// `m` is the stage mapping's pipelined chunk latency at the
/// microbatch's cache position, inter-chip hops charge
/// `trace::shard_transfer_cost` per microbatch, and the serial
/// baseline is the 1-chip full-mapping chunk cost of the same work.
pub(crate) fn sharded_chunk_step(
    model: &DecodeModel,
    sharded: &mut ShardedBackend,
    params: &CimParams,
    slots: &mut [BatchSlot],
    ws: &mut ChunkWorkspace,
    inputs: &[(usize, &[i32])],
) -> PipelineTimeline {
    let cfg = &model.cfg;
    let lanes: usize = inputs.iter().map(|&(_, toks)| toks.len()).sum();
    ws.ensure(lanes);
    // cache length of every group BEFORE any K/V append this step
    let bases: Vec<usize> = inputs.iter().map(|&(si, _)| slots[si].kv.len()).collect();
    prefill::embed_chunk(model, ws, inputs, &bases);
    for stage in sharded.stages.iter_mut() {
        for li in 0..stage.layer_ops.len() {
            let ops = stage.layer_ops[li];
            prefill::layer_chunk(
                model,
                &mut stage.backend,
                ops,
                stage.lo + li,
                slots,
                ws,
                inputs,
                &bases,
                lanes,
            );
        }
    }
    prefill::head_chunk(model, ws, lanes);
    prefill::finish_chunk(
        cfg,
        Some(&sharded.full_mapping),
        params,
        slots,
        ws,
        inputs,
        &bases,
    );

    // --- per-stage timeline of this step ---
    let stage_ns: Vec<Vec<f64>> = sharded
        .stages
        .iter()
        .map(|stage| {
            let sm = stage.mapping();
            inputs
                .iter()
                .enumerate()
                .map(|(gi, &(_, toks))| {
                    trace::stage_chunk_ns(
                        cfg,
                        sm,
                        params,
                        bases[gi],
                        toks.len(),
                        stage.depth(),
                    )
                })
                .collect()
        })
        .collect();
    let transfer_ns: Vec<f64> = inputs
        .iter()
        .map(|&(_, toks)| {
            trace::shard_transfer_cost(params, toks.len())
                .latency
                .comm_ns
        })
        .collect();
    let mut timeline = pipeline_timeline(&stage_ns, &transfer_ns);
    // honest 1-chip baseline: the full mapping's pipelined chunk cost
    // for the same microbatches, back to back, no transfers
    timeline.serial_ns = inputs
        .iter()
        .enumerate()
        .map(|(gi, &(_, toks))| {
            prefill_chunk_cost(cfg, &sharded.full_mapping, params, bases[gi], toks.len())
                .chunk_ns
        })
        .sum();
    timeline
}

/// Accumulated pipeline observability of a sharded engine: per-stage
/// busy time, total span, transfer bill and the 1-chip serial
/// baseline, summed over every sharded step since construction (or the
/// last [`take`](crate::sim::decode::BatchDecodeEngine::take_pipeline_stats)).
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    /// Sharded steps accumulated.
    pub steps: u64,
    /// Busy time per stage (ns), summed over steps.
    pub stage_busy_ns: Vec<f64>,
    /// Summed step makespans (ns).
    pub span_ns: f64,
    /// Summed inter-chip transfer latency charged (ns).
    pub transfer_ns: f64,
    /// Summed 1-chip serial baseline of the same work (ns).
    pub serial_ns: f64,
    /// The most recent step's full timeline.
    pub last: Option<PipelineTimeline>,
}

impl PipelineStats {
    pub(crate) fn record(&mut self, timeline: PipelineTimeline) {
        self.steps += 1;
        if self.stage_busy_ns.len() < timeline.stage_busy_ns.len() {
            self.stage_busy_ns.resize(timeline.stage_busy_ns.len(), 0.0);
        }
        for (acc, b) in self.stage_busy_ns.iter_mut().zip(&timeline.stage_busy_ns) {
            *acc += b;
        }
        self.span_ns += timeline.makespan_ns;
        self.transfer_ns += timeline.transfer_ns;
        self.serial_ns += timeline.serial_ns;
        self.last = Some(timeline);
    }

    /// Per-stage occupancy: fraction of the accumulated span each
    /// stage spent busy (1.0 = never idle).
    pub fn stage_occupancy(&self) -> Vec<f64> {
        if self.span_ns <= 0.0 {
            return vec![0.0; self.stage_busy_ns.len()];
        }
        self.stage_busy_ns
            .iter()
            .map(|b| (b / self.span_ns).min(1.0))
            .collect()
    }

    /// Idle fraction of the stage-time grid over the accumulated span.
    pub fn bubble_fraction(&self) -> f64 {
        let stages = self.stage_busy_ns.len();
        if stages == 0 || self.span_ns <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.stage_busy_ns.iter().sum();
        (1.0 - busy / (stages as f64 * self.span_ns)).max(0.0)
    }

    /// Modeled throughput gain over one chip doing the same work
    /// serially.
    pub fn speedup_vs_1chip(&self) -> f64 {
        if self.span_ns <= 0.0 {
            return 1.0;
        }
        self.serial_ns / self.span_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn stage_ranges_partition_contiguously() {
        for n_layers in 1..=9usize {
            for shards in 1..=6usize {
                let ranges = stage_ranges(n_layers, shards);
                assert_eq!(ranges.len(), shards.clamp(1, n_layers));
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges.last().unwrap().1, n_layers);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                }
                let depths: Vec<usize> = ranges.iter().map(|&(a, b)| b - a).collect();
                assert!(depths.iter().all(|&d| d >= 1));
                let (min, max) = (
                    *depths.iter().min().unwrap(),
                    *depths.iter().max().unwrap(),
                );
                assert!(max - min <= 1, "depths differ by at most one");
            }
        }
    }

    #[test]
    fn stage_ranges_clamp_oversharded_models() {
        // more shards than layers: one layer per stage, no empty stages
        assert_eq!(stage_ranges(2, 4), vec![(0, 1), (1, 2)]);
        assert_eq!(stage_ranges(1, 8), vec![(0, 1)]);
        assert_eq!(stage_ranges(4, 0), vec![(0, 4)]);
    }

    #[test]
    fn sharded_backend_programs_every_layer_once() {
        let cfg = ModelConfig::tiny();
        let model = DecodeModel::synth(cfg, 7);
        let params = CimParams::default();
        let sb = ShardedBackend::program(&model, &params, Strategy::DenseMap, 2, 1);
        assert_eq!(sb.stage_count(), 2);
        assert_eq!(sb.ranges(), vec![(0, 1), (1, 2)]);
        let mut total_ops = 0usize;
        for stage in &sb.stages {
            assert_eq!(stage.layer_ops.len(), stage.depth());
            total_ops += stage.mapping().ops.len();
            // every stage-local index addresses the stage chip's op list
            for lo in &stage.layer_ops {
                for idx in [lo.wq, lo.wk, lo.wv, lo.wo, lo.ffn1, lo.ffn2] {
                    assert!(idx < stage.mapping().ops.len());
                }
            }
        }
        assert_eq!(total_ops, model.ops.len(), "layer partition covers all ops");
    }

    #[test]
    fn pipeline_stats_accumulate_and_normalize() {
        let mut stats = PipelineStats::default();
        assert_eq!(stats.speedup_vs_1chip(), 1.0);
        assert_eq!(stats.bubble_fraction(), 0.0);
        let tl = pipeline_timeline(&[vec![100.0, 100.0], vec![100.0, 100.0]], &[0.0, 0.0]);
        let serial = tl.serial_ns;
        stats.record(tl);
        assert_eq!(stats.steps, 1);
        assert_eq!(stats.stage_busy_ns.len(), 2);
        assert!((stats.span_ns - 300.0).abs() < 1e-9);
        assert!((stats.serial_ns - serial).abs() < 1e-9);
        assert!(stats.speedup_vs_1chip() > 1.0);
        let occ = stats.stage_occupancy();
        assert_eq!(occ.len(), 2);
        assert!(occ.iter().all(|&o| o > 0.0 && o <= 1.0));
        assert!(stats.bubble_fraction() > 0.0 && stats.bubble_fraction() < 1.0);
    }
}
