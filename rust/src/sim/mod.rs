//! Chip-level simulation: [`exec`] provides functional (numeric)
//! execution of mapped Monarch operators on emulated crossbars, used to
//! validate that mapping + scheduling compute correct results; the
//! analytical latency/energy side lives in `scheduler::timing`.

pub mod exec;
pub mod trace;

pub use exec::FunctionalChip;
