//! Chip-level simulation: [`exec`] provides functional (numeric)
//! execution of mapped operators on emulated crossbars, used to validate
//! that mapping + scheduling compute correct results; [`decode`] runs a
//! full decoder-only transformer on that chip autoregressively (KV
//! cache, greedy sampling, per-token cost accounting); [`prefill`]
//! ingests prompts position-parallel (chunked prefill — lanes =
//! positions through the same batched replay); [`speculate`] layers
//! draft-propose / batched-verify speculative decoding on top of the
//! chunk engine (K+1 positions per verify replay, bit-identical to
//! greedy); [`shard`] programs the decoder's layers across N chips as
//! contiguous pipeline stages and overlaps their analog windows over
//! in-flight microbatches (bit-identical to the 1-chip path);
//! [`divergence`] measures the token-level accuracy impact of analog
//! (noise/ADC-capped) decode against the exact path; the analytical
//! latency/energy side lives in `scheduler::timing` and [`trace`].

pub mod decode;
pub mod divergence;
pub mod exec;
pub mod prefill;
pub mod shard;
pub mod speculate;
pub mod trace;

pub use decode::{BatchDecodeEngine, DecodeEngine, DecodeModel, DecodeResult};
pub use divergence::{measure_divergence, Divergence};
pub use exec::FunctionalChip;
pub use prefill::KvCache;
pub use shard::{stage_ranges, PipelineStats, ShardedBackend};
pub use speculate::{self_draft_model, SpeculativeEngine, SpeculativeResult};
